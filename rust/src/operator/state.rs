//! Operator state σ: window sets per key, shardable for VSN sharing (§5).
//!
//! In SN setups each instance owns a private `SharedState` (1 shard, no
//! contention). In VSN setups all instances share one `SharedState`;
//! STRETCH's correctness argument (Theorem 3) guarantees each key is
//! updated by exactly one instance per epoch, so shard mutexes only
//! arbitrate *different* keys hashing to the same shard.

use crate::time::{EventTime, TIME_MAX};
use crate::tuple::{mix64, Key};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// The paper's ⟨ζ, l, k⟩ window instance, generalized to the set of I
/// instances sharing (key, l): `states[i]` is the ζ of input i.
#[derive(Debug)]
pub struct WindowSet<S> {
    pub key: Key,
    /// Left boundary l (inclusive).
    pub l: EventTime,
    /// One ζ per input stream.
    pub states: Vec<S>,
}

impl<S: Default> WindowSet<S> {
    pub fn new(key: Key, l: EventTime, inputs: usize) -> Self {
        WindowSet { key, l, states: (0..inputs).map(|_| S::default()).collect() }
    }
}

/// Per-key state: the list of window sets (σ[k][ℓ] in Alg. 2), earliest
/// first, plus the expiry-index bookkeeping.
#[derive(Debug)]
pub struct KeyState<S> {
    pub wins: VecDeque<WindowSet<S>>,
    /// The expiry timestamp currently scheduled in the owner's heap
    /// (TIME_MAX = none). Keeps at most one live heap entry per key.
    pub next_expiry: EventTime,
}

impl<S> Default for KeyState<S> {
    fn default() -> Self {
        KeyState { wins: VecDeque::new(), next_expiry: TIME_MAX }
    }
}

impl<S> KeyState<S> {
    /// Expiry time of the earliest window set (l + WS), if any.
    pub fn front_expiry(&self, ws: EventTime) -> Option<EventTime> {
        self.wins.front().map(|w| w.l + ws)
    }

    /// Find the window set with left boundary `l` (wins are l-ordered).
    pub fn find_mut(&mut self, l: EventTime) -> Option<&mut WindowSet<S>> {
        // windows are few per key; linear scan beats binary search at n<=8
        self.wins.iter_mut().find(|w| w.l == l)
    }
}

/// Sharded key → KeyState map.
pub struct SharedState<S> {
    shards: Vec<Mutex<HashMap<Key, KeyState<S>>>>,
    mask: u64,
}

/// Default shard count for VSN sharing (power of two).
pub const DEFAULT_SHARDS: usize = 64;

impl<S: Send + 'static> SharedState<S> {
    pub fn new(shards: usize) -> Arc<Self> {
        let shards = shards.next_power_of_two();
        Arc::new(SharedState {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            mask: shards as u64 - 1,
        })
    }

    /// Private (SN) state: one shard, zero sharing intended.
    pub fn private() -> Arc<Self> {
        Self::new(1)
    }

    #[inline]
    fn shard_of(&self, k: Key) -> &Mutex<HashMap<Key, KeyState<S>>> {
        &self.shards[(mix64(k) & self.mask) as usize]
    }

    /// Shard index of a key (for building shard-grouped key plans).
    #[inline]
    pub fn shard_index(&self, k: Key) -> usize {
        (mix64(k) & self.mask) as usize
    }

    /// Process a group of keys that all live in shard `shard_idx`,
    /// locking the shard ONCE (the §Perf fix for constant-key operators
    /// like ScaleJoin, where per-key locking dominated the hot path).
    /// `f` returns `false` to remove the key's state.
    pub fn with_key_group(
        &self,
        shard_idx: usize,
        keys: &[Key],
        mut f: impl FnMut(Key, &mut KeyState<S>) -> bool,
    ) {
        let mut shard = self.shards[shard_idx].lock().unwrap();
        for &k in keys {
            debug_assert_eq!(self.shard_index(k), shard_idx);
            let entry = shard.entry(k).or_default();
            if !f(k, entry) {
                shard.remove(&k);
            }
        }
    }

    /// Run `f` with the key's state (created on demand). If `f` returns
    /// `false`, the key's state is removed (the σ.remove of Alg. 2).
    pub fn with_key<R>(&self, k: Key, f: impl FnOnce(&mut KeyState<S>) -> (R, bool)) -> R {
        let mut shard = self.shard_of(k).lock().unwrap();
        let entry = shard.entry(k).or_default();
        let (r, keep) = f(entry);
        if !keep {
            shard.remove(&k);
        }
        r
    }

    /// Run `f` on the key's state only if present (no creation).
    pub fn with_existing<R>(
        &self,
        k: Key,
        f: impl FnOnce(&mut KeyState<S>) -> (R, bool),
    ) -> Option<R> {
        let mut shard = self.shard_of(k).lock().unwrap();
        match shard.get_mut(&k) {
            Some(entry) => {
                let (r, keep) = f(entry);
                if !keep {
                    shard.remove(&k);
                }
                Some(r)
            }
            None => None,
        }
    }

    /// Visit every (key, state) — used to rebuild expiry indexes on epoch
    /// switches. Shards are locked one at a time.
    pub fn scan(&self, mut f: impl FnMut(Key, &mut KeyState<S>)) {
        for shard in &self.shards {
            let mut guard = shard.lock().unwrap();
            for (k, st) in guard.iter_mut() {
                f(*k, st);
            }
        }
    }

    /// Total number of keys (diagnostics).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop everything (between experiment phases).
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_key_creates_and_removes() {
        let st: Arc<SharedState<u32>> = SharedState::new(4);
        st.with_key(7, |ks| {
            ks.wins.push_back(WindowSet::new(7, 0, 1));
            ((), true)
        });
        assert_eq!(st.len(), 1);
        st.with_key(7, |_| ((), false));
        assert_eq!(st.len(), 0);
    }

    #[test]
    fn with_existing_does_not_create() {
        let st: Arc<SharedState<u32>> = SharedState::new(4);
        assert!(st.with_existing(1, |_| ((), true)).is_none());
        assert_eq!(st.len(), 0);
    }

    #[test]
    fn scan_visits_all() {
        let st: Arc<SharedState<u32>> = SharedState::new(8);
        for k in 0..100u64 {
            st.with_key(k, |ks| {
                ks.wins.push_back(WindowSet::new(k, k as i64, 1));
                ((), true)
            });
        }
        let mut seen = 0;
        st.scan(|_, _| seen += 1);
        assert_eq!(seen, 100);
    }

    #[test]
    fn find_mut_by_boundary() {
        let mut ks: KeyState<u32> = KeyState::default();
        ks.wins.push_back(WindowSet::new(1, 0, 2));
        ks.wins.push_back(WindowSet::new(1, 10, 2));
        assert!(ks.find_mut(10).is_some());
        assert!(ks.find_mut(5).is_none());
        assert_eq!(ks.front_expiry(30), Some(30));
    }

    #[test]
    fn concurrent_distinct_keys() {
        let st: Arc<SharedState<u64>> = SharedState::new(16);
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let st = st.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        let k = t * 1000 + i;
                        st.with_key(k, |ks| {
                            ks.wins.push_back(WindowSet::new(k, 0, 1));
                            ks.wins[0].states[0] += 1;
                            ((), true)
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(st.len(), 4000);
    }
}
