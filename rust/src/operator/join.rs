//! Join operators: the generalized `J+` and the ScaleJoin instantiation
//! (Operator 3, Appendix D).
//!
//! ScaleJoin performs a Cartesian band join of two streams in a
//! skew-resilient way: every tuple is seen by every instance (f_MK returns
//! *all* keys); each instance compares the tuple against the previous
//! tuples stored under its keys; the tuple itself is stored under exactly
//! one key chosen round-robin by a shared counter — consistent across
//! instances because the ESG delivers the same tuple sequence to all.
//!
//! The comparison inner loop is the paper's compute hot-spot (its join
//! throughput metric *is* comparisons/second). It runs either as a scalar
//! loop or through a [`BatchMatcher`] — the PJRT-compiled Pallas kernel
//! wired in by `crate::runtime` (DESIGN.md §Hardware-Adaptation).

use crate::operator::state::WindowSet;
use crate::operator::{Ctx, OperatorDef, OperatorLogic, WindowType};
use crate::time::WindowSpec;
use crate::tuple::{Key, Payload, Tuple};
use std::collections::VecDeque;
use std::sync::Arc;

/// A join predicate + combiner over payloads of the two streams.
pub trait JoinPredicate: Send + Sync + 'static {
    type L: Payload;
    type R: Payload;
    type Out: Payload;

    fn matches(&self, l: &Self::L, r: &Self::R) -> bool;
    fn combine(&self, l: &Self::L, r: &Self::R) -> Self::Out;
}

/// Batched evaluation of a join predicate: probe one tuple against the
/// opposite window's stored tuples, pushing the indices that match.
/// Implemented by the PJRT offload engine (`crate::runtime::offload`);
/// `None` means "use the scalar loop".
pub trait BatchMatcher<P: JoinPredicate>: Send + Sync {
    /// Probe a left tuple against the stored right window.
    fn probe_l(&self, probe: &P::L, stored: &StoredWindow<P::R>, out: &mut Vec<u32>);
    /// Probe a right tuple against the stored left window.
    fn probe_r(&self, probe: &P::R, stored: &StoredWindow<P::L>, out: &mut Vec<u32>);
}

/// Tuples stored by one (key, input) window instance, oldest first, with
/// their timestamps for purging.
pub struct StoredWindow<P> {
    pub ts: VecDeque<crate::time::EventTime>,
    pub payload: VecDeque<P>,
}

impl<P> Default for StoredWindow<P> {
    fn default() -> Self {
        StoredWindow { ts: VecDeque::new(), payload: VecDeque::new() }
    }
}

impl<P> StoredWindow<P> {
    #[inline]
    pub fn len(&self) -> usize {
        self.payload.len()
    }
    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }
    #[inline]
    pub fn push(&mut self, ts: crate::time::EventTime, p: P) {
        self.ts.push_back(ts);
        self.payload.push_back(p);
    }
    /// Purge tuples with `ts + WS < now` (Operator 3 L18-19).
    #[inline]
    pub fn purge_before(&mut self, cutoff: crate::time::EventTime) {
        while let Some(&front) = self.ts.front() {
            if front < cutoff {
                self.ts.pop_front();
                self.payload.pop_front();
            } else {
                break;
            }
        }
    }
}

/// ScaleJoin window state ζ: the shared round-robin counter + the stored
/// tuples of this key, kept *typed per side* so the comparison inner loop
/// (the paper's hot-spot) runs over homogeneous contiguous payloads with
/// no enum dispatch (§Perf: this alone bought back most of the gap to 1T).
pub struct SjState<L, R> {
    pub c: u64,
    pub left: StoredWindow<L>,
    pub right: StoredWindow<R>,
}

impl<L, R> Default for SjState<L, R> {
    fn default() -> Self {
        SjState { c: 0, left: StoredWindow::default(), right: StoredWindow::default() }
    }
}

/// Two-sided payload: which stream a tuple belongs to is also encoded in
/// `Tuple::input`, but the payload enum keeps the hot path monomorphic.
#[derive(Clone, Debug)]
pub enum Either<L, R> {
    L(L),
    R(R),
}

impl<L: Default, R> Default for Either<L, R> {
    fn default() -> Self {
        Either::L(L::default())
    }
}

/// ScaleJoin (Operator 3): `J+(WA=δ, WS, 2, f_MK = all keys, single, …)`.
pub struct ScaleJoinLogic<P: JoinPredicate> {
    pub pred: Arc<P>,
    /// Number of round-robin keys (1000 in the paper).
    pub n_keys: u64,
    /// Optional batched matcher (PJRT offload).
    pub matcher: Option<Arc<dyn BatchMatcher<P>>>,
    /// Probe-result scratch (indices), reused across calls.
    _priv: (),
}

impl<P: JoinPredicate> ScaleJoinLogic<P> {
    pub fn new(pred: P, n_keys: u64) -> Self {
        ScaleJoinLogic { pred: Arc::new(pred), n_keys, matcher: None, _priv: () }
    }

    pub fn with_matcher(mut self, m: Arc<dyn BatchMatcher<P>>) -> Self {
        self.matcher = Some(m);
        self
    }
}

impl<P: JoinPredicate> OperatorLogic for ScaleJoinLogic<P> {
    type In = Either<P::L, P::R>;
    type Out = P::Out;
    /// Both sides live in states[0] (typed); states[1] stays empty —
    /// the I = 2 window-set shape is preserved at the framework level.
    type State = SjState<P::L, P::R>;

    fn keys(&self, _t: &Tuple<Self::In>, keys: &mut Vec<Key>) {
        // f_MK returns {1..n_keys}: every instance sees every tuple
        keys.extend(0..self.n_keys);
    }

    fn update(&self, w: &mut WindowSet<Self::State>, t: &Tuple<Self::In>, ctx: &mut Ctx<'_, Self::Out>) {
        let ws = ctx.win_right - w.l; // WS
        let st = &mut w.states[0];
        // increase the per-window counter consistently (Operator 3 L10-11)
        st.c += 1;
        let c = st.c;
        // purge stale tuples from the opposite window (L18-19), compare
        // (L20-21), then round-robin store (L22-23)
        let cutoff = t.ts - ws + 1; // keep t' with t'.ts + WS >= t.ts + 1
        let store_here = c % self.n_keys == w.key;
        match &t.payload {
            Either::L(l) => {
                let opp = &mut st.right;
                opp.purge_before(cutoff);
                ctx.record_comparisons(opp.len() as u64);
                if let Some(m) = &self.matcher {
                    let mut idx = Vec::with_capacity(4);
                    m.probe_l(l, opp, &mut idx);
                    for i in idx {
                        let out = self.pred.combine(l, &opp.payload[i as usize]);
                        ctx.emit(out);
                    }
                } else {
                    // explicit slice halves: tight, unrollable inner loops
                    let (a, b) = opp.payload.as_slices();
                    for r in a {
                        if self.pred.matches(l, r) {
                            let out = self.pred.combine(l, r);
                            ctx.emit(out);
                        }
                    }
                    for r in b {
                        if self.pred.matches(l, r) {
                            let out = self.pred.combine(l, r);
                            ctx.emit(out);
                        }
                    }
                }
                if store_here {
                    st.left.push(t.ts, l.clone());
                }
            }
            Either::R(r) => {
                let opp = &mut st.left;
                opp.purge_before(cutoff);
                ctx.record_comparisons(opp.len() as u64);
                if let Some(m) = &self.matcher {
                    let mut idx = Vec::with_capacity(4);
                    m.probe_r(r, opp, &mut idx);
                    for i in idx {
                        let out = self.pred.combine(&opp.payload[i as usize], r);
                        ctx.emit(out);
                    }
                } else {
                    let (a, b) = opp.payload.as_slices();
                    for l in a {
                        if self.pred.matches(l, r) {
                            let out = self.pred.combine(l, r);
                            ctx.emit(out);
                        }
                    }
                    for l in b {
                        if self.pred.matches(l, r) {
                            let out = self.pred.combine(l, r);
                            ctx.emit(out);
                        }
                    }
                }
                if store_here {
                    st.right.push(t.ts, r.clone());
                }
            }
        }
    }

    fn slide(&self, w: &mut WindowSet<Self::State>, new_l: crate::time::EventTime) -> bool {
        // f_S: purge tuples that can no longer match (ts < new_l)
        w.states[0].left.purge_before(new_l);
        w.states[0].right.purge_before(new_l);
        // ScaleJoin keys are permanent (counters must persist)
        true
    }

    fn has_output(&self) -> bool {
        false // no f_O → expiry fast-forwards (WA = δ)
    }

    fn keys_are_constant(&self) -> bool {
        true // f_MK = {1..n_keys} for every tuple
    }
}


/// Build a ScaleJoin operator (Operator 3): WA = δ, window size `ws`.
pub fn scalejoin_op<P: JoinPredicate>(
    name: &'static str,
    ws: crate::time::EventTime,
    pred: P,
    n_keys: u64,
) -> OperatorDef<ScaleJoinLogic<P>> {
    OperatorDef::new(
        name,
        WindowSpec::new(crate::time::DELTA, ws),
        2,
        WindowType::Single,
        ScaleJoinLogic::new(pred, n_keys),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OperatorMetrics;
    use crate::operator::state::SharedState;
    use crate::operator::OperatorCore;
    use crate::tuple::Mapper;

    /// Test predicate: integers within ±2 match; combine = (l, r).
    struct Band2;
    impl JoinPredicate for Band2 {
        type L = i64;
        type R = i64;
        type Out = (i64, i64);
        fn matches(&self, l: &i64, r: &i64) -> bool {
            (l - r).abs() <= 2
        }
        fn combine(&self, l: &i64, r: &i64) -> (i64, i64) {
            (*l, *r)
        }
    }

    fn run_join(
        n_instances: usize,
        n_keys: u64,
        tuples: Vec<Tuple<Either<i64, i64>>>,
    ) -> (Vec<(i64, i64)>, u64) {
        let def = scalejoin_op("sj", 100, Band2, n_keys);
        let shared = SharedState::new(8);
        let metrics = OperatorMetrics::new(n_instances);
        let f_mu = Mapper::hash_mod(n_instances);
        let mut cores: Vec<_> = (0..n_instances)
            .map(|i| OperatorCore::new(def.clone(), i, shared.clone(), metrics.clone()))
            .collect();
        let mut out = Vec::new();
        let mut comparisons = 0;
        for t in &tuples {
            // every instance sees every tuple (same merged sequence)
            for core in cores.iter_mut() {
                let mut sink = |o: Tuple<(i64, i64)>| out.push(o.payload);
                let mut ctx = Ctx::new(&mut sink);
                core.process(t, &f_mu, &mut ctx);
                comparisons += ctx.comparisons;
            }
        }
        (out, comparisons)
    }

    fn l(ts: i64, v: i64) -> Tuple<Either<i64, i64>> {
        Tuple::data_on(ts, 0, Either::L(v))
    }
    fn r(ts: i64, v: i64) -> Tuple<Either<i64, i64>> {
        Tuple::data_on(ts, 1, Either::R(v))
    }

    #[test]
    fn basic_band_match() {
        let (mut out, _) = run_join(1, 4, vec![l(1, 10), r(2, 11), r(3, 50), l(4, 49)]);
        out.sort();
        assert_eq!(out, vec![(10, 11), (49, 50)]);
    }

    #[test]
    fn parallel_instances_find_same_matches_once() {
        // Cartesian correctness: results must be identical (as multisets)
        // for any Π — Definition 1 via Theorem 3.
        let mut tuples = Vec::new();
        let mut rng = crate::util::Rng::new(7);
        for i in 0..200i64 {
            let v = rng.gen_range(30) as i64;
            if rng.chance(0.5) {
                tuples.push(l(i, v));
            } else {
                tuples.push(r(i, v));
            }
        }
        let (mut out1, cmp1) = run_join(1, 10, tuples.clone());
        let (mut out3, cmp3) = run_join(3, 10, tuples);
        out1.sort();
        out3.sort();
        assert_eq!(out1, out3, "Π=1 and Π=3 must produce identical matches");
        assert!(!out1.is_empty());
        // every pair compared exactly once regardless of Π
        assert_eq!(cmp1, cmp3);
    }

    #[test]
    fn comparisons_equal_cross_product_within_window() {
        // With a huge window and no purging: k-th tuple compares against
        // all previous tuples of the opposite stream.
        let tuples = vec![l(1, 0), l(2, 0), r(3, 0), r(4, 0), l(5, 0)];
        // r(3) vs 2 L; r(4) vs 2 L; l(5) vs 2 R  → 6 comparisons
        let (_, cmp) = run_join(2, 5, tuples);
        assert_eq!(cmp, 6);
    }

    #[test]
    fn window_purges_old_tuples() {
        // WS=100: an L at ts=0 cannot match an R at ts=150
        let (out, _) = run_join(1, 4, vec![l(0, 10), r(150, 10)]);
        assert!(out.is_empty());
    }

    #[test]
    fn round_robin_stores_each_tuple_once() {
        // With n_keys=4 and Π=1, feed 8 tuples; total stored = 8.
        let def = scalejoin_op("sj", 1000, Band2, 4);
        let shared = SharedState::new(4);
        let metrics = OperatorMetrics::new(1);
        let f_mu = Mapper::hash_mod(1);
        let mut core = OperatorCore::new(def, 0, shared.clone(), metrics);
        for i in 0..8i64 {
            let t = l(i, i);
            let mut sink = |_o: Tuple<(i64, i64)>| {};
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        let mut stored = 0;
        shared.scan(|_, ks| {
            for w in &ks.wins {
                stored += w.states[0].left.len() + w.states[0].right.len();
            }
        });
        assert_eq!(stored, 8);
    }

    #[test]
    fn self_join_via_two_inputs() {
        // Q6 pattern: same logical stream fed on both inputs
        let (mut out, _) = run_join(1, 4, vec![l(1, 5), r(1, 5), l(2, 6), r(2, 6)]);
        out.sort();
        // l(1,5)–r(1,5): r arrives second, matches l → (5,5)
        // l(2,6) matches r(1,5)? |6-5|<=2 yes → (6,5)
        // r(2,6) matches l(1,5) (|5-6|<=2 → (5,6)) and l(2,6) → (6,6)
        assert_eq!(out, vec![(5, 5), (5, 6), (6, 5), (6, 6)]);
    }
}
