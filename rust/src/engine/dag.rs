//! True DAG topologies over shared Elastic ScaleGates: fan-out and
//! fan-in, the §2 shape [`crate::engine::pipeline`]'s linear chains
//! could not express.
//!
//! A DAG edge-group is ONE shared gate:
//!
//! * **fan-out** — a stage feeding several downstream stages publishes
//!   once into its ESG_out; every downstream stage registers as an extra
//!   *reader group* (a contiguous reader-slot range) on that same gate.
//!   The ESG's exactly-once-per-reader delivery (Def. 6) gives each
//!   consumer stage the full stream with zero duplication of the data
//!   plane — the SN baseline would clone per downstream.
//! * **fan-in** — a stage merging several upstreams owns ONE ESG_in with
//!   one *source-slot group* per upstream stage; the existing
//!   multi-source cooperative merge delivers one globally ts-sorted
//!   stream (the readiness bound is the min over every upstream's worker
//!   clocks, so watermarks compose across branches for free).
//! * **per-edge control** — every consumer stage of a gate owns a
//!   reserved control slot (after all worker source slots) and a control
//!   *tag*: control tuples are broadcast to all reader groups, so a
//!   worker only adopts specs whose `Tuple::input` matches its stage's
//!   tag. Each stage therefore stays independently elastic, exactly as
//!   in the linear builder.
//!
//! Grouping rule: consumer stages sharing an upstream must consume the
//! *identical* upstream set (the gate is a hyperedge — a reader group
//! sees everything published into the gate, so differing upstream sets
//! would leak one branch's tuples into another). The diamond
//! `S → {A, B} → J` satisfies it: A and B both consume exactly `{S}`,
//! J consumes exactly `{A, B}`.
//!
//! Construction is two-phase: [`DagBuilder::source`]/[`DagBuilder::node`]
//! record typed per-node spawn closures; [`DagBuilder::build`] validates
//! the topology, lays out every gate's slot geometry (offsets per
//! stage), then runs the closures — gates are created lazily by the
//! first participant and shared through a type-erased store (the handle
//! types guarantee every participant agrees on the payload type).

use crate::engine::ingress::StretchIngress;
use crate::engine::pipeline::{ControlInjector, Pipeline, StageHandle, VsnStage};
use crate::engine::vsn::{EngineClock, StageIo, VsnEngine, VsnOptions};
use crate::operator::{OperatorDef, OperatorLogic};
use crate::scalegate::{Esg, EsgConfig, GateEntry, ReaderHandle, SourceHandle};
use crate::time::TIME_MIN;
use crate::tuple::{Payload, Tuple};
use std::any::Any;
use std::collections::BTreeMap;
use std::fmt;
use std::marker::PhantomData;

/// Typed reference to a declared DAG node; the payload type parameter is
/// the node's *output*, so edges type-check at `node()` call sites.
pub struct NodeHandle<P> {
    idx: usize,
    _m: PhantomData<fn() -> P>,
}

impl<P> Clone for NodeHandle<P> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<P> Copy for NodeHandle<P> {}

impl<P> NodeHandle<P> {
    /// Index of this node in `Pipeline::stages` (declaration order).
    pub fn index(&self) -> usize {
        self.idx
    }
}

/// Topology validation errors from [`DagBuilder::build`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DagError {
    /// The builder holds no nodes.
    Empty,
    /// A `node()` call listed the same upstream more than once.
    DuplicateUpstream { node: &'static str },
    /// Two consumers share an upstream but not the full upstream set —
    /// the shared gate would leak one branch's stream into the other.
    FanOutSetConflict { node: &'static str },
    /// A handle passed to `build()` as a sink is consumed by another node.
    SinkNotEgress { node: &'static str },
    /// A node with no consumers was not passed to `build()` as a sink —
    /// its output gate would have no reader and fill up.
    MissingSink { node: &'static str },
    /// The same sink handle was passed twice.
    DuplicateSink { node: &'static str },
    /// More than 256 consumer stages on one gate (control tags are u8).
    TooManyConsumers { node: &'static str },
}

impl fmt::Display for DagError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DagError::Empty => write!(f, "DAG has no nodes"),
            DagError::DuplicateUpstream { node } => {
                write!(f, "node `{node}` lists the same upstream twice")
            }
            DagError::FanOutSetConflict { node } => write!(
                f,
                "node `{node}` is consumed by stages with differing upstream sets \
                 (consumers of a shared gate must consume the identical upstream set)"
            ),
            DagError::SinkNotEgress { node } => {
                write!(f, "sink `{node}` is consumed by another node")
            }
            DagError::MissingSink { node } => write!(
                f,
                "node `{node}` has no consumers but was not declared a sink \
                 (its output gate would have no reader)"
            ),
            DagError::DuplicateSink { node } => write!(f, "sink `{node}` passed twice"),
            DagError::TooManyConsumers { node } => {
                write!(f, "gate fed by `{node}` has more than 256 consumer stages")
            }
        }
    }
}

impl std::error::Error for DagError {}

/// Slot-range assignment of one node on its (possibly shared) gates.
#[derive(Clone, Copy, Debug, Default)]
struct NodePlan {
    /// Edge-group of the node's ESG_in (`None` ⇒ external source node).
    in_group: Option<usize>,
    /// Edge-group of the node's ESG_out (`None` ⇒ sink node).
    out_group: Option<usize>,
    /// First reader slot of this stage on its ESG_in.
    reader_base: usize,
    /// First source slot of this stage on its ESG_out.
    source_base: usize,
    /// Reserved control slot on the ESG_in (consumer stages only).
    ctrl_slot: usize,
    /// Control tag on the ESG_in (consumer index within the gate).
    ctrl_tag: u8,
}

/// Untyped geometry of one edge-group gate, fixed before any gate is
/// created: slot counts plus which slots start active.
struct GateGeom {
    cfg: EsgConfig,
    active_sources: Vec<usize>,
    active_readers: Vec<usize>,
}

/// A created-but-not-fully-claimed gate: participants take their slot
/// ranges out of the `Option`s as their spawn closures run.
struct PendingGate<T: GateEntry> {
    esg: Esg<T>,
    sources: Vec<Option<SourceHandle<T>>>,
    readers: Vec<Option<ReaderHandle<T>>>,
}

impl<T: GateEntry> PendingGate<T> {
    fn build(geom: &GateGeom) -> Self {
        // all slots start inactive; activation is per-slot because each
        // participant's active prefix sits at its own offset
        let (esg, sources, readers) = Esg::new(geom.cfg, 0, 0);
        // fail fast (release builds too): a silently inactive slot would
        // not error later, it would hang the topology — no data flows and
        // readiness never advances past the dead group
        if !geom.active_sources.is_empty() {
            let ok = esg.add_sources(&geom.active_sources, TIME_MIN);
            assert!(ok, "fresh gate rejected initial source activation (geometry bug)");
        }
        if !geom.active_readers.is_empty() {
            let ok = esg.add_readers_at(&geom.active_readers, 0);
            assert!(ok, "fresh gate rejected initial reader activation (geometry bug)");
        }
        PendingGate {
            esg,
            sources: sources.into_iter().map(Some).collect(),
            readers: readers.into_iter().map(Some).collect(),
        }
    }

    fn take_sources(&mut self, base: usize, n: usize) -> Vec<SourceHandle<T>> {
        (base..base + n)
            .map(|i| self.sources[i].take().expect("source slot claimed twice"))
            .collect()
    }

    fn take_source(&mut self, i: usize) -> SourceHandle<T> {
        self.sources[i].take().expect("control slot claimed twice")
    }

    fn take_readers(&mut self, base: usize, n: usize) -> Vec<ReaderHandle<T>> {
        (base..base + n)
            .map(|i| self.readers[i].take().expect("reader slot claimed twice"))
            .collect()
    }
}

/// Shared state the spawn closures build against.
struct BuildCtx {
    geoms: Vec<GateGeom>,
    /// One lazily created gate per edge-group (`PendingGate<Tuple<P>>`
    /// behind `Any`; the handle types guarantee agreement on `P`).
    gates: Vec<Option<Box<dyn Any>>>,
    /// Sink nodes' private output gates, keyed by node index.
    sink_gates: Vec<Option<Box<dyn Any>>>,
    clock: EngineClock,
}

impl BuildCtx {
    /// The edge-group's gate, created on first touch.
    fn gate<T: GateEntry>(&mut self, g: usize) -> &mut PendingGate<T> {
        if self.gates[g].is_none() {
            self.gates[g] = Some(Box::new(PendingGate::<T>::build(&self.geoms[g])));
        }
        self.gates[g]
            .as_mut()
            .unwrap()
            .downcast_mut::<PendingGate<T>>()
            .expect("edge payload type mismatch (handle types guarantee agreement)")
    }
}

type Spawn<In> =
    Box<dyn FnOnce(&mut BuildCtx, &NodePlan) -> (Box<dyn StageHandle>, Vec<StretchIngress<In>>)>;

struct NodeDecl<In: Payload + Default> {
    name: &'static str,
    /// Upstream node indices (empty ⇔ external source node).
    ups: Vec<usize>,
    max: usize,
    initial: usize,
    gate_capacity: usize,
    spawn: Spawn<In>,
}

/// Builder for DAG topologies: declare nodes with [`source`]/[`node`]
/// (handles enforce edge types), then [`build`] into a running
/// [`Pipeline`]. `In` is the external input payload (every source node
/// consumes it); the sink output payload is a parameter of [`build`]
/// itself, so one builder value can grow through stages of arbitrary
/// intermediate types — which is what lets the linear
/// [`crate::engine::pipeline::PipelineBuilder`] be a thin façade over
/// this type.
///
/// ```ignore
/// let mut b = DagBuilder::<Trade>::new();
/// let s = b.source(trade_filter_op(64), opts_s);
/// let a = b.node(left_leg_op(64), opts_a, &[s]);   // fan-out: a and b
/// let c = b.node(right_leg_op(64), opts_b, &[s]);  //   share s's gate
/// let j = b.node(hedge_join_op(ws, 32), opts_j, &[a, c]); // fan-in
/// let pipeline = b.build(&[j])?;
/// ```
///
/// [`source`]: DagBuilder::source
/// [`node`]: DagBuilder::node
/// [`build`]: DagBuilder::build
pub struct DagBuilder<In: Payload + Default> {
    nodes: Vec<NodeDecl<In>>,
    clock: EngineClock,
    /// Per-node spawn-thread affinity (declaration order): `build()` runs
    /// node `i`'s spawn closure pinned to `spawn_cores[i]` when set.
    spawn_cores: Vec<Option<usize>>,
}

impl<In: Payload + Default> Default for DagBuilder<In> {
    fn default() -> Self {
        Self::new()
    }
}

impl<In: Payload + Default> DagBuilder<In> {
    pub fn new() -> Self {
        DagBuilder { nodes: Vec::new(), clock: EngineClock::new(), spawn_cores: Vec::new() }
    }

    /// Pin each node's spawn closure to a core during [`build`]: gate slot
    /// arrays and `Log` segments are allocated (and first-written) inside
    /// those closures, so on NUMA machines first-touch places them on the
    /// pinned core's socket. Worker threads spawned inside the closure
    /// also inherit the mask until they re-pin themselves. Indices follow
    /// declaration order; missing or `None` entries leave the build thread
    /// unpinned for that node.
    ///
    /// [`build`]: DagBuilder::build
    pub fn set_spawn_cores(&mut self, cores: Vec<Option<usize>>) {
        self.spawn_cores = cores;
    }

    /// Number of declared nodes so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Declare an external source node: `opts.upstreams` ingress wrappers
    /// feed its private ESG_in (control rides the wrappers, Alg. 5).
    pub fn source<L>(&mut self, def: OperatorDef<L>, opts: VsnOptions) -> NodeHandle<L::Out>
    where
        L: OperatorLogic<In = In>,
        L::Out: Default,
    {
        let idx = self.nodes.len();
        let name = def.name;
        let (max, initial, gate_capacity) = (opts.max, opts.initial, opts.gate_capacity);
        let spawn: Spawn<In> = Box::new(move |ctx, plan| {
            let (esg_in, in_sources, in_readers) =
                Esg::new(opts.in_gate_config(), opts.upstreams, opts.initial);
            let (esg_out, out_sources, source_base) =
                claim_out_gate::<L::Out>(ctx, plan, &opts, idx);
            let io = StageIo {
                esg_in,
                in_sources,
                in_readers,
                esg_out,
                out_sources,
                reader_base: 0,
                source_base,
                ctrl_tag: 0,
            };
            let max = opts.max;
            let (engine, ingress) = VsnEngine::setup_with_gates(def, opts, io, ctx.clock.clone());
            (Box::new(VsnStage::new(name, engine, None, max)) as Box<dyn StageHandle>, ingress)
        });
        self.nodes.push(NodeDecl { name, ups: Vec::new(), max, initial, gate_capacity, spawn });
        NodeHandle { idx, _m: PhantomData }
    }

    /// Declare an internal node consuming one or more upstream nodes.
    /// One upstream = a chain hop; several = fan-in (one source-slot
    /// group per upstream on the shared ESG_in). Several nodes declaring
    /// the same upstream set = fan-out (each becomes a reader group on
    /// the shared gate). `opts.upstreams` is ignored — the input sources
    /// are the upstream stages' workers plus this node's control slot.
    pub fn node<L>(
        &mut self,
        def: OperatorDef<L>,
        opts: VsnOptions,
        ups: &[NodeHandle<L::In>],
    ) -> NodeHandle<L::Out>
    where
        L: OperatorLogic,
        L::In: Default,
        L::Out: Default,
    {
        assert!(!ups.is_empty(), "node() needs upstreams; use source() for external inputs");
        let idx = self.nodes.len();
        let name = def.name;
        let (max, initial, gate_capacity) = (opts.max, opts.initial, opts.gate_capacity);
        let ups_idx: Vec<usize> = ups.iter().map(|h| h.idx).collect();
        let spawn: Spawn<In> = Box::new(move |ctx, plan| {
            let g_in = plan.in_group.expect("node() always has an in-group");
            let (esg_in, in_readers, ctrl_src) = {
                let pg = ctx.gate::<Tuple<L::In>>(g_in);
                (
                    pg.esg.clone(),
                    pg.take_readers(plan.reader_base, opts.max),
                    pg.take_source(plan.ctrl_slot),
                )
            };
            let (esg_out, out_sources, source_base) =
                claim_out_gate::<L::Out>(ctx, plan, &opts, idx);
            let io = StageIo {
                esg_in,
                in_sources: Vec::new(),
                in_readers,
                esg_out,
                out_sources,
                reader_base: plan.reader_base,
                source_base,
                ctrl_tag: plan.ctrl_tag,
            };
            let max = opts.max;
            let (engine, _no_ingress) =
                VsnEngine::setup_with_gates(def, opts, io, ctx.clock.clone());
            let injector =
                ControlInjector::new(ctrl_src, engine.control.clone()).with_tag(plan.ctrl_tag);
            (
                Box::new(VsnStage::new(name, engine, Some(injector), max)) as Box<dyn StageHandle>,
                Vec::new(),
            )
        });
        self.nodes.push(NodeDecl { name, ups: ups_idx, max, initial, gate_capacity, spawn });
        NodeHandle { idx, _m: PhantomData }
    }

    /// Validate the topology, lay out every shared gate, spawn every
    /// stage, and return the running [`Pipeline`]. `sinks` must list
    /// exactly the nodes no other node consumes; their output gates get
    /// `opts.egress_readers` reader ends each, concatenated into
    /// `Pipeline::egress` in the given order. `Out` (every sink's output
    /// payload) is inferred from the sink handles.
    pub fn build<Out: Payload + Default>(
        self,
        sinks: &[NodeHandle<Out>],
    ) -> Result<Pipeline<In, Out>, DagError> {
        let n = self.nodes.len();
        if n == 0 {
            return Err(DagError::Empty);
        }

        // -- edge-groups: consumers keyed by their (sorted) upstream set
        struct Group {
            ups: Vec<usize>,
            consumers: Vec<usize>,
        }
        let mut group_of: BTreeMap<Vec<usize>, usize> = BTreeMap::new();
        let mut groups: Vec<Group> = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            if node.ups.is_empty() {
                continue;
            }
            let mut key = node.ups.clone();
            key.sort_unstable();
            if key.windows(2).any(|w| w[0] == w[1]) {
                return Err(DagError::DuplicateUpstream { node: node.name });
            }
            let g = *group_of.entry(key.clone()).or_insert_with(|| {
                groups.push(Group { ups: key, consumers: Vec::new() });
                groups.len() - 1
            });
            groups[g].consumers.push(i);
        }

        // -- every upstream node publishes into exactly one gate
        let mut plans: Vec<NodePlan> = vec![NodePlan::default(); n];
        for (g, group) in groups.iter().enumerate() {
            for &u in &group.ups {
                if plans[u].out_group.is_some() {
                    return Err(DagError::FanOutSetConflict { node: self.nodes[u].name });
                }
                plans[u].out_group = Some(g);
            }
        }

        // -- sinks = nodes nothing consumes; must match the caller's list
        let mut is_sink = vec![false; n];
        for s in sinks {
            if is_sink[s.idx] {
                return Err(DagError::DuplicateSink { node: self.nodes[s.idx].name });
            }
            if plans[s.idx].out_group.is_some() {
                return Err(DagError::SinkNotEgress { node: self.nodes[s.idx].name });
            }
            is_sink[s.idx] = true;
        }
        for i in 0..n {
            if plans[i].out_group.is_none() && !is_sink[i] {
                return Err(DagError::MissingSink { node: self.nodes[i].name });
            }
        }

        // -- per-group slot layout + geometry
        let mut geoms: Vec<GateGeom> = Vec::with_capacity(groups.len());
        for (g, group) in groups.iter().enumerate() {
            if group.consumers.len() > u8::MAX as usize + 1 {
                return Err(DagError::TooManyConsumers { node: self.nodes[group.ups[0]].name });
            }
            let mut capacity = 0usize;
            let mut src_off = 0usize;
            let mut active_sources = Vec::new();
            for &u in &group.ups {
                plans[u].source_base = src_off;
                active_sources.extend(src_off..src_off + self.nodes[u].initial);
                src_off += self.nodes[u].max;
                capacity = capacity.max(self.nodes[u].gate_capacity);
            }
            let mut rdr_off = 0usize;
            let mut active_readers = Vec::new();
            for (j, &c) in group.consumers.iter().enumerate() {
                plans[c].in_group = Some(g);
                plans[c].reader_base = rdr_off;
                plans[c].ctrl_slot = src_off + j;
                plans[c].ctrl_tag = j as u8;
                active_readers.extend(rdr_off..rdr_off + self.nodes[c].initial);
                rdr_off += self.nodes[c].max;
                capacity = capacity.max(self.nodes[c].gate_capacity);
            }
            geoms.push(GateGeom {
                cfg: EsgConfig::for_gate(src_off + group.consumers.len(), rdr_off, capacity),
                active_sources,
                active_readers,
            });
        }

        // -- spawn every stage in declaration (= topological) order
        let mut ctx = BuildCtx {
            gates: (0..geoms.len()).map(|_| None).collect(),
            geoms,
            sink_gates: (0..n).map(|_| None).collect(),
            clock: self.clock.clone(),
        };
        let mut stages: Vec<Box<dyn StageHandle>> = Vec::with_capacity(n);
        let mut ingress: Vec<StretchIngress<In>> = Vec::new();
        let spawn_cores = self.spawn_cores;
        for (i, node) in self.nodes.into_iter().enumerate() {
            // first-touch: run the spawn closure (gate + log allocation)
            // on the node's assigned core; restored on drop each iteration
            let _pin = spawn_cores
                .get(i)
                .copied()
                .flatten()
                .map(crate::runtime::placement::PinGuard::pin);
            let (handle, node_ingress) = (node.spawn)(&mut ctx, &plans[i]);
            stages.push(handle);
            ingress.extend(node_ingress);
        }

        // -- collect sink egress readers + gates (caller's sink order)
        let mut egress: Vec<ReaderHandle<Tuple<Out>>> = Vec::new();
        let mut out_gates: Vec<Esg<Tuple<Out>>> = Vec::new();
        for s in sinks {
            let pg = ctx.sink_gates[s.idx]
                .as_mut()
                .expect("sink gate missing")
                .downcast_mut::<PendingGate<Tuple<Out>>>()
                .expect("sink payload type mismatch (handle types guarantee agreement)");
            let readers = pg.readers.len();
            egress.extend(pg.take_readers(0, readers));
            out_gates.push(pg.esg.clone());
        }

        Ok(Pipeline { clock: self.clock, ingress, egress, out_gates, stages })
    }
}

/// Claim a node's output-gate ends: a slot range on the shared edge-group
/// gate, or a fresh private gate for sink nodes (stashed for
/// `build()`'s egress collection).
fn claim_out_gate<P: Payload + Default>(
    ctx: &mut BuildCtx,
    plan: &NodePlan,
    opts: &VsnOptions,
    idx: usize,
) -> (Esg<Tuple<P>>, Vec<SourceHandle<Tuple<P>>>, usize) {
    match plan.out_group {
        Some(g) => {
            let pg = ctx.gate::<Tuple<P>>(g);
            (pg.esg.clone(), pg.take_sources(plan.source_base, opts.max), plan.source_base)
        }
        None => {
            let (esg, sources, readers) =
                Esg::new(opts.out_gate_config(), opts.initial, opts.egress_readers);
            ctx.sink_gates[idx] = Some(Box::new(PendingGate {
                esg: esg.clone(),
                sources: Vec::new(),
                readers: readers.into_iter().map(Some).collect(),
            }));
            (esg, sources, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operator::map::{map_stage_op, MapLogic, MapStageLogic};
    use crate::util::Backoff;

    struct IdMap;
    impl MapLogic for IdMap {
        type In = u64;
        type Out = u64;
        fn flat_map(&self, t: &Tuple<u64>, emit: &mut dyn FnMut(u64)) {
            emit(t.payload)
        }
    }

    fn id_op(name: &'static str) -> OperatorDef<MapStageLogic<IdMap>> {
        map_stage_op(name, IdMap, 8)
    }

    fn opts(initial: usize, max: usize) -> VsnOptions {
        VsnOptions { initial, max, gate_capacity: 4096, ..Default::default() }
    }

    #[test]
    fn diamond_topology_builds_and_flows() {
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let a = b.node(id_op("a"), opts(1, 2), &[s]);
        let c = b.node(id_op("b"), opts(1, 2), &[s]);
        let j = b.node(id_op("j"), opts(1, 2), &[a, c]);
        let mut p = b.build(&[j]).unwrap();
        assert_eq!(p.stages.len(), 4);
        assert_eq!(p.ingress.len(), 1);
        assert_eq!(p.egress.len(), 1);
        assert_eq!(p.out_gates.len(), 1);

        let mut ing = p.ingress.remove(0);
        let n = 500u64;
        for i in 0..n {
            ing.add(Tuple::data(i as i64, i)).unwrap();
        }
        ing.heartbeat(1_000_000).unwrap();
        // fan-out duplicates the stream per branch; fan-in merges both
        let mut reader = p.egress.remove(0);
        let mut got = 0u64;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let mut buf: Vec<Tuple<u64>> = Vec::new();
        let mut last_ts = i64::MIN;
        let mut idle = Backoff::active();
        while got < 2 * n && std::time::Instant::now() < deadline {
            buf.clear();
            if reader.get_batch(&mut buf, 128) == 0 {
                idle.snooze();
                continue;
            }
            idle.reset();
            for t in &buf {
                if t.kind.is_data() {
                    assert!(t.ts >= last_ts, "fan-in merge must stay ts-sorted");
                    last_ts = t.ts;
                    got += 1;
                }
            }
        }
        p.shutdown();
        assert_eq!(got, 2 * n, "each branch must deliver the full stream exactly once");
    }

    #[test]
    fn conflicting_fanout_sets_rejected() {
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let s2 = b.source(id_op("s2"), opts(1, 2));
        let _a = b.node(id_op("a"), opts(1, 2), &[s]);
        let _c = b.node(id_op("b"), opts(1, 2), &[s, s2]);
        // `s` would publish into two different gates
        let err = b.build::<u64>(&[]).unwrap_err();
        assert!(matches!(err, DagError::FanOutSetConflict { .. }), "{err}");
    }

    #[test]
    fn sink_validation() {
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let a = b.node(id_op("a"), opts(1, 2), &[s]);
        // `a` is the sink, `s` is consumed: passing `s` must fail…
        let err = b.build(&[s, a]).unwrap_err();
        assert!(matches!(err, DagError::SinkNotEgress { .. }), "{err}");
        // …and omitting `a` must fail too
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let _a = b.node(id_op("a"), opts(1, 2), &[s]);
        let err = b.build::<u64>(&[]).unwrap_err();
        assert!(matches!(err, DagError::MissingSink { .. }), "{err}");
    }

    #[test]
    fn empty_dag_rejected() {
        let b = DagBuilder::<u64>::new();
        assert_eq!(b.build::<u64>(&[]).unwrap_err(), DagError::Empty);
    }

    #[test]
    fn duplicate_upstream_rejected() {
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let _a = b.node(id_op("a"), opts(1, 2), &[s, s]);
        let err = b.build::<u64>(&[]).unwrap_err();
        assert!(matches!(err, DagError::DuplicateUpstream { .. }), "{err}");
    }

    #[test]
    fn multi_sink_dag_exposes_all_egress() {
        // S fans out to two sinks: both must surface readers + gates
        let mut b = DagBuilder::<u64>::new();
        let s = b.source(id_op("s"), opts(1, 2));
        let a = b.node(id_op("a"), opts(1, 2), &[s]);
        let c = b.node(id_op("b"), opts(1, 2), &[s]);
        let mut p = b.build(&[a, c]).unwrap();
        assert_eq!(p.egress.len(), 2);
        assert_eq!(p.out_gates.len(), 2);
        let mut ing = p.ingress.remove(0);
        for i in 0..100u64 {
            ing.add(Tuple::data(i as i64, i)).unwrap();
        }
        ing.heartbeat(1_000_000).unwrap();
        for mut r in p.egress.drain(..) {
            let mut got = 0;
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
            let mut idle = Backoff::active();
            while got < 100 && std::time::Instant::now() < deadline {
                match r.get() {
                    Some(t) if t.kind.is_data() => {
                        got += 1;
                        idle.reset();
                    }
                    Some(_) => idle.reset(),
                    None => idle.snooze(),
                }
            }
            assert_eq!(got, 100, "each sink sees the full stream");
        }
        p.shutdown();
    }
}
