//! The reconfiguration barrier (`waitForInstances(𝕆)`, Alg. 4 L18).
//!
//! A generation barrier with run-time party count: every instance of the
//! current epoch 𝕆 processes the same merged tuple sequence, hence
//! observes the same trigger (W > γ) and calls `wait(|𝕆|)` with the same
//! count — membership never changes *while* a barrier is pending
//! (reconfigurations are serialized by the epoch protocol, §6).
//!
//! lint: lock-free — two atomics, no locks, no condvars.
//!
//! # Memory-ordering protocol
//!
//! Two-phase: (1) **arrive** — each party AcqRel-increments `arrived`,
//! building a release sequence that makes every party's pre-barrier
//! writes visible to the last arrival; (2) **release** — the last
//! arrival Release-stores the bumped `generation`, and the waiters'
//! Acquire spin loads pair with it. The two edges compose so that
//! everything sequenced before ANY party's `wait` happens-before
//! everything sequenced after EVERY party's `wait` — the property
//! `do_reconfig` relies on when it reads other workers' health marks
//! and replay state after the barrier.

use crate::util::Backoff;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

pub struct EpochBarrier {
    arrived: AtomicUsize,
    generation: AtomicU64,
}

impl Default for EpochBarrier {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochBarrier {
    pub fn new() -> Self {
        EpochBarrier { arrived: AtomicUsize::new(0), generation: AtomicU64::new(0) }
    }

    /// Block until `parties` threads of the current generation arrived.
    /// Returns `true` for exactly one caller (the "leader"), which the
    /// engine uses for single-shot bookkeeping (metrics; membership is
    /// arbitrated by the ESG itself).
    pub fn wait(&self, parties: usize) -> bool {
        debug_assert!(parties > 0);
        // ORDERING: Acquire — `gen` must be this generation's value, i.e.
        // happen-after the previous generation's Release bump.
        let gen = self.generation.load(Ordering::Acquire);
        // ORDERING: AcqRel is load-bearing on BOTH halves here: Release
        // chains each party's pre-barrier writes into `arrived`'s release
        // sequence; Acquire lets the last arrival observe all of them
        // before it opens the next phase. Not weakenable.
        let pos = self.arrived.fetch_add(1, Ordering::AcqRel) + 1;
        if pos == parties {
            // last arrival: reset, then release the others.
            // ORDERING: Release — the reset is ordered before the
            // `generation` publish below, and waiters of the NEXT
            // generation Acquire-load `generation` first, so they can
            // never increment a stale `arrived`.
            self.arrived.store(0, Ordering::Release);
            // ORDERING: Release pairs with the waiters' Acquire spin
            // below — the generation bump publishes the reset and every
            // party's pre-barrier writes.
            self.generation.store(gen + 1, Ordering::Release);
            true
        } else {
            // spin → yield → short sleeps: on 1-core boxes sleeping lets
            // the stragglers run (the shared spin-then-yield policy).
            let mut idle = Backoff::new(Duration::from_micros(50));
            // ORDERING: Acquire pairs with the leader's Release bump —
            // leaving the loop happens-after every party arrived.
            while self.generation.load(Ordering::Acquire) == gen {
                idle.snooze();
            }
            false
        }
    }

    /// ORDERING: Acquire pairs with the leader's Release bump — an
    /// observed generation implies the barrier that produced it is done.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_party_passes_immediately() {
        let b = EpochBarrier::new();
        assert!(b.wait(1));
        assert_eq!(b.generation(), 1);
    }

    #[test]
    fn releases_all_and_elects_one_leader() {
        let b = Arc::new(EpochBarrier::new());
        let n = 4;
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let b = b.clone();
                std::thread::spawn(move || b.wait(n))
            })
            .collect();
        let leaders =
            handles.into_iter().map(|h| h.join().unwrap()).filter(|&l| l).count();
        assert_eq!(leaders, 1);
        assert_eq!(b.generation(), 1);
    }

    #[test]
    fn reusable_across_generations() {
        let b = Arc::new(EpochBarrier::new());
        for round in 0..5 {
            let handles: Vec<_> = (0..3)
                .map(|_| {
                    let b = b.clone();
                    std::thread::spawn(move || b.wait(3))
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(b.generation(), round + 1);
        }
    }
}
