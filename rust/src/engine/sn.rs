//! The shared-nothing baseline engine (§2.2, Alg. 1 + Alg. 2).
//!
//! This is the paper's SN model — the "Flink-like" comparison system of
//! §8: each ⟨upstream, instance⟩ pair exchanges tuples over a *dedicated*
//! queue; `forwardSN` routes a tuple to every instance responsible for at
//! least one of its keys (cloning it — the Theorem-1 data duplication);
//! each instance merge-sorts its input queues (implicit watermarks,
//! Def. 3) and runs `processSN` over its *private* state. The egress
//! merge-sorts the instances' outputs, as the paper assumes for
//! order-sensitive analysis (§8).

use crate::engine::vsn::EngineClock;
use crate::metrics::{Histogram, OperatorMetrics};
use crate::operator::state::SharedState;
use crate::operator::{Ctx, OperatorCore, OperatorDef, OperatorLogic};
use crate::tuple::{Mapper, Tuple};
use crate::util::pool;
use crate::util::spsc::{self, Consumer, Producer, PushError};
use crate::util::Backoff;
use crate::watermark::MergeSorter;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// SN engine options.
#[derive(Clone, Debug)]
pub struct SnOptions {
    /// Π(O): number of operator instances.
    pub parallelism: usize,
    /// Number of upstream (ingress) instances running forwardSN.
    pub upstreams: usize,
    /// Capacity of each dedicated queue (backpressure bound).
    pub queue_capacity: usize,
    /// Tuples moved per queue synchronization (SPSC push_slice /
    /// pop_chunk granularity on the instance and egress hops).
    pub batch: usize,
}

impl Default for SnOptions {
    fn default() -> Self {
        SnOptions { parallelism: 1, upstreams: 1, queue_capacity: 1 << 12, batch: 128 }
    }
}

impl SnOptions {
    /// Apply the `[batch]` section of an experiment config.
    pub fn with_batch(mut self, tuning: &crate::config::BatchTuning) -> Self {
        self.batch = tuning.queue.max(1);
        self
    }
}

/// A running SN engine.
pub struct SnEngine<L: OperatorLogic> {
    pub metrics: Arc<OperatorMetrics>,
    _marker: std::marker::PhantomData<fn(L)>,
    /// Total enqueues performed by forwardSN — compare with tuples_in to
    /// quantify the duplication overhead (Theorem 1).
    pub forwarded: Arc<AtomicU64>,
    pub clock: EngineClock,
    pub mapper: Mapper,
    running: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Upstream endpoint: runs `forwardSN` (Alg. 1).
pub struct SnIngress<L: OperatorLogic> {
    logic: Arc<L>,
    mapper: Mapper,
    queues: Vec<Producer<Tuple<L::In>>>,
    keys_buf: Vec<crate::tuple::Key>,
    targets: Vec<bool>,
    /// Per-target clone staging for [`forward_batch`](Self::forward_batch)
    /// (lazily sized to the queue count).
    staging: Vec<Vec<Tuple<L::In>>>,
    forwarded: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl<L: OperatorLogic> SnIngress<L> {
    /// forwardSN: route `t` to every instance responsible for one of its
    /// keys; heartbeats broadcast to all instances. Zero-copy fan-out
    /// (§Perf memory discipline): the LAST responsible target receives
    /// the original tuple by move — only the first n−1 targets are
    /// clones, so single-target routing (the common case) and Π = 1
    /// broadcasts never touch the allocator. Theorem 1's duplication
    /// overhead is the *extra* copies, and n hits cost exactly n − 1.
    pub fn forward(&mut self, t: Tuple<L::In>) {
        if !t.kind.is_data() {
            if let Some((last, rest)) = self.queues.split_last_mut() {
                for q in rest.iter_mut() {
                    push_blocking(q, t.clone(), &self.running);
                }
                push_blocking(last, t, &self.running);
            }
            return;
        }
        self.keys_buf.clear();
        self.logic.keys(&t, &mut self.keys_buf);
        self.targets.iter_mut().for_each(|x| *x = false);
        for &k in &self.keys_buf {
            self.targets[self.mapper.map(k)] = true;
        }
        // a tuple may have no keys (Def. 4 allows f_MK = ∅): forwarded
        // nowhere, like the per-target loop it replaces
        let Some(last) = self.targets.iter().rposition(|&hit| hit) else {
            return;
        };
        let mut n = 1u64;
        for j in 0..last {
            if self.targets[j] {
                push_blocking(&mut self.queues[j], t.clone(), &self.running);
                n += 1;
            }
        }
        push_blocking(&mut self.queues[last], t, &self.running);
        // ORDERING: Relaxed — duplication-overhead counter (Theorem 1
        // accounting); read only in end-of-run reports.
        self.forwarded.fetch_add(n, Ordering::Relaxed);
    }

    /// Batched forwardSN: route a ts-sorted run, staging per target
    /// queue and flushing each with batched pushes — one tail publish
    /// per (run, target) instead of per (tuple, target). Zero-copy like
    /// [`forward`](Self::forward): the last responsible target stages
    /// the original by move, only the first n−1 stage clones. Drains
    /// `run` (the caller's buffer keeps its allocation, like the other
    /// batch APIs).
    pub fn forward_batch(&mut self, run: &mut Vec<Tuple<L::In>>) {
        if self.staging.is_empty() {
            self.staging = (0..self.queues.len()).map(|_| Vec::new()).collect();
        }
        let mut n = 0u64;
        for t in run.drain(..) {
            if !t.kind.is_data() {
                // order matters: drain staged data ahead of the broadcast
                self.flush_staging();
                if let Some((last, rest)) = self.queues.split_last_mut() {
                    for q in rest.iter_mut() {
                        push_blocking(q, t.clone(), &self.running);
                    }
                    push_blocking(last, t, &self.running);
                }
                continue;
            }
            self.keys_buf.clear();
            self.logic.keys(&t, &mut self.keys_buf);
            self.targets.iter_mut().for_each(|x| *x = false);
            for &k in &self.keys_buf {
                self.targets[self.mapper.map(k)] = true;
            }
            let Some(last) = self.targets.iter().rposition(|&hit| hit) else {
                continue;
            };
            for j in 0..last {
                if self.targets[j] {
                    self.staging[j].push(t.clone());
                    n += 1;
                }
            }
            // zero-copy: the last responsible target takes the original
            self.staging[last].push(t);
            n += 1;
        }
        self.flush_staging();
        // ORDERING: Relaxed — duplication-overhead counter, as in
        // `forward`.
        self.forwarded.fetch_add(n, Ordering::Relaxed);
    }

    fn flush_staging(&mut self) {
        for (j, buf) in self.staging.iter_mut().enumerate() {
            push_slice_blocking(&mut self.queues[j], buf, &self.running);
            // burst decay: one hot run must not pin a staging row's
            // inflated capacity forever
            pool::shrink_excess(buf, pool::DEFAULT_SHRINK_CAP);
        }
    }

    /// Advance all downstream channels when this upstream idles.
    pub fn heartbeat(&mut self, ts: crate::time::EventTime)
    where
        L::In: Default,
    {
        self.forward(Tuple::heartbeat(ts));
    }
}

fn push_blocking<T>(q: &mut Producer<T>, mut v: T, running: &AtomicBool) {
    let mut b = Backoff::active();
    loop {
        match q.try_push(v) {
            Ok(()) => return,
            Err(PushError::Closed(_)) => return,
            Err(PushError::Full(back)) => {
                // ORDERING: Acquire pairs with shutdown's Release store —
                // the escape hatch out of backpressure at teardown.
                if !running.load(Ordering::Acquire) {
                    return;
                }
                v = back;
                b.snooze();
            }
        }
    }
}

/// Batched [`push_blocking`]: drain `buf` into the queue with one tail
/// publish per accepted chunk, spinning on backpressure.
fn push_slice_blocking<T>(q: &mut Producer<T>, buf: &mut Vec<T>, running: &AtomicBool) {
    let mut b = Backoff::active();
    while !buf.is_empty() {
        if q.push_slice(buf, usize::MAX) == 0 {
            // ORDERING: Acquire pairs with shutdown's Release store.
            if q.is_closed() || !running.load(Ordering::Acquire) {
                buf.clear();
                return;
            }
            b.snooze();
        } else {
            b.reset();
        }
    }
}

/// Egress endpoint: merge-sorts the instances' output channels and
/// records throughput + latency (driven by the caller, like the paper's
/// sink).
pub struct SnEgress<Out: Clone + Send + Sync + 'static> {
    channels: Vec<Consumer<Tuple<Out>>>,
    sorter: MergeSorter<Out>,
    /// Chunked-pop scratch (batched intake).
    intake: Vec<Tuple<Out>>,
    batch: usize,
    pub clock: EngineClock,
    pub count: u64,
    pub latency_us: Arc<Histogram>,
}

impl<Out: Clone + Send + Sync + 'static> SnEgress<Out> {
    /// Pull everything available into the sorter, one chunk at a time.
    fn intake_all(&mut self) {
        for (ch, c) in self.channels.iter_mut().enumerate() {
            while c.pop_chunk(&mut self.intake, self.batch) > 0 {
                for t in self.intake.drain(..) {
                    self.sorter.offer(ch, t);
                }
            }
        }
    }

    /// Drain available output tuples; returns how many data tuples passed.
    pub fn poll(&mut self) -> usize {
        self.intake_all();
        let mut n = 0;
        while let Some(t) = self.sorter.pop_ready() {
            if t.kind.is_data() {
                self.count += 1;
                n += 1;
                if t.ingest_us > 0 {
                    let now = self.clock.now_us();
                    self.latency_us.record(now.saturating_sub(t.ingest_us));
                }
            }
        }
        n
    }

    pub fn drain_until(&mut self, expected: u64, timeout: std::time::Duration) -> u64 {
        let t0 = std::time::Instant::now();
        let mut backoff = Backoff::active();
        while self.count < expected && t0.elapsed() < timeout {
            if self.poll() == 0 {
                backoff.snooze();
            } else {
                backoff.reset();
            }
        }
        self.count
    }

    /// Like [`poll`](Self::poll) but hands every ready data tuple to `f`.
    pub fn poll_tuples(&mut self, f: &mut dyn FnMut(&Tuple<Out>)) -> usize {
        self.intake_all();
        let mut n = 0;
        while let Some(t) = self.sorter.pop_ready() {
            if t.kind.is_data() {
                self.count += 1;
                n += 1;
                if t.ingest_us > 0 {
                    let now = self.clock.now_us();
                    self.latency_us.record(now.saturating_sub(t.ingest_us));
                }
                f(&t);
            }
        }
        n
    }
}

impl<L: OperatorLogic> SnEngine<L>
where
    L::In: Default,
    L::Out: Default,
{
    /// Build the SN topology: `upstreams × parallelism` dedicated input
    /// queues, one instance thread per o_j with private state, and a
    /// caller-driven egress.
    pub fn setup(
        def: OperatorDef<L>,
        opts: SnOptions,
    ) -> (Self, Vec<SnIngress<L>>, SnEgress<L::Out>) {
        let pi = opts.parallelism;
        let u = opts.upstreams;
        assert!(pi >= 1 && u >= 1);
        let mapper = Mapper::hash_mod(pi);
        let metrics = OperatorMetrics::new(pi);
        let forwarded = Arc::new(AtomicU64::new(0));
        let running = Arc::new(AtomicBool::new(true));
        let clock = EngineClock::new();

        // queues[u][j]
        let mut ingress_producers: Vec<Vec<Producer<Tuple<L::In>>>> =
            (0..u).map(|_| Vec::with_capacity(pi)).collect();
        let mut instance_consumers: Vec<Vec<Consumer<Tuple<L::In>>>> =
            (0..pi).map(|_| Vec::with_capacity(u)).collect();
        for uu in 0..u {
            for jj in 0..pi {
                let (p, c) = spsc::spsc(opts.queue_capacity);
                ingress_producers[uu].push(p);
                instance_consumers[jj].push(c);
            }
        }
        // egress channels [j]
        let mut egress_producers = Vec::with_capacity(pi);
        let mut egress_consumers = Vec::with_capacity(pi);
        for _ in 0..pi {
            let (p, c) = spsc::spsc::<Tuple<L::Out>>(opts.queue_capacity);
            egress_producers.push(p);
            egress_consumers.push(c);
        }

        let batch = opts.batch.max(1);
        let mut threads = Vec::with_capacity(pi);
        for (j, (consumers, mut egress)) in
            instance_consumers.into_iter().zip(egress_producers).enumerate()
        {
            let def = def.clone();
            let metrics = metrics.clone();
            let mapper = mapper.clone();
            let running = running.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-sn-{j}", def.name))
                    .spawn(move || {
                        run_instance::<L>(
                            def, j, consumers, &mut egress, mapper, metrics, running, batch,
                        )
                    })
                    .expect("spawn sn instance"),
            );
        }

        let ingress = ingress_producers
            .into_iter()
            .map(|queues| SnIngress {
                logic: def.logic.clone(),
                mapper: mapper.clone(),
                targets: vec![false; pi],
                queues,
                keys_buf: Vec::with_capacity(16),
                staging: Vec::new(),
                forwarded: forwarded.clone(),
                running: running.clone(),
            })
            .collect();

        let egress = SnEgress {
            sorter: MergeSorter::new(pi),
            channels: egress_consumers,
            intake: Vec::with_capacity(batch),
            batch,
            clock: clock.clone(),
            count: 0,
            latency_us: Arc::new(Histogram::new()),
        };

        (
            SnEngine { metrics, forwarded, clock, mapper, running, threads, _marker: std::marker::PhantomData },
            ingress,
            egress,
        )
    }

    pub fn shutdown(&mut self) {
        // ORDERING: Release pairs with the instances' Acquire loop checks.
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl<L: OperatorLogic> Drop for SnEngine<L> {
    fn drop(&mut self) {
        // ORDERING: Release pairs with the instances' Acquire loop checks.
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One SN instance thread: merge-sort dedicated queues (chunked pops),
/// processSN, forward outputs (plus watermark heartbeats) to the egress
/// channel with batched pushes.
#[allow(clippy::too_many_arguments)]
fn run_instance<L: OperatorLogic>(
    def: OperatorDef<L>,
    j: usize,
    mut consumers: Vec<Consumer<Tuple<L::In>>>,
    egress: &mut Producer<Tuple<L::Out>>,
    mapper: Mapper,
    metrics: Arc<OperatorMetrics>,
    running: Arc<AtomicBool>,
    batch: usize,
) where
    L::Out: Default,
{
    let mut core: OperatorCore<L> = OperatorCore::new(def, j, SharedState::private(), metrics.clone());
    let mut sorter: MergeSorter<L::In> = MergeSorter::new(consumers.len());
    let mut backoff = Backoff::pooled();
    let mut last_emitted = crate::time::TIME_MIN;
    let mut in_buf: Vec<Tuple<L::In>> = Vec::with_capacity(batch);
    // outputs stage here and leave via one batched push per flush point
    let mut out_buf: Vec<Tuple<L::Out>> = Vec::with_capacity(batch);
    // ORDERING: Acquire pairs with shutdown's Release store.
    while running.load(Ordering::Acquire) {
        // intake: one head/tail synchronization per chunk, not per tuple
        let mut moved = false;
        for (ch, c) in consumers.iter_mut().enumerate() {
            while c.pop_chunk(&mut in_buf, batch) > 0 {
                for t in in_buf.drain(..) {
                    sorter.offer(ch, t);
                }
                moved = true;
            }
        }
        // process ready tuples
        let mut processed = 0u32;
        let mut drained = true;
        while let Some(t) = sorter.pop_ready() {
            processed += 1;
            let grew = core.observe(t.ts);
            let mut emitted = 0u64;
            {
                let last = &mut last_emitted;
                let ob = &mut out_buf;
                let mut sink = |o: Tuple<L::Out>| {
                    emitted += 1;
                    *last = (*last).max(o.ts);
                    ob.push(o);
                };
                let mut ctx = Ctx::new(&mut sink);
                ctx.ingest_us = t.ingest_us;
                if grew {
                    core.advance(&mapper, &mut ctx);
                }
                if t.kind.is_data() {
                    core.handle_input(&t, &mapper, &mut ctx);
                    core.metrics.record_in(j);
                }
                if ctx.comparisons > 0 {
                    core.metrics.record_comparisons(ctx.comparisons);
                }
            }
            if emitted > 0 {
                core.metrics.record_out(emitted);
            }
            if grew && emitted == 0 {
                // watermark heartbeat so the egress sorter can progress;
                // never below anything already emitted (channel sortedness)
                let hb_ts = core.watermark().max(last_emitted);
                out_buf.push(Tuple::heartbeat(hb_ts));
                last_emitted = hb_ts;
            }
            if out_buf.len() >= batch {
                push_slice_blocking(egress, &mut out_buf, &running);
            }
            if processed > 256 {
                drained = false;
                break; // fairness: intake again
            }
        }
        // Heartbeats advance channel clocks without being queued by the
        // sorter; fold the combined watermark into the core so windows
        // expire when rates drop to zero (explicit watermarks, §2.3).
        // ONLY once every ready tuple has been processed — folding early
        // would expire windows ahead of their contributors.
        let wm = sorter.watermark();
        if drained && wm > core.watermark() && core.observe(wm) {
            let mut emitted = 0u64;
            {
                let last = &mut last_emitted;
                let ob = &mut out_buf;
                let mut sink = |o: Tuple<L::Out>| {
                    emitted += 1;
                    *last = (*last).max(o.ts);
                    ob.push(o);
                };
                let mut ctx = Ctx::new(&mut sink);
                core.advance(&mapper, &mut ctx);
            }
            if emitted > 0 {
                core.metrics.record_out(emitted);
            }
            let hb_ts = core.watermark().max(last_emitted);
            out_buf.push(Tuple::heartbeat(hb_ts));
            last_emitted = hb_ts;
        }
        // per-iteration flush: idle loops must not sit on staged outputs
        push_slice_blocking(egress, &mut out_buf, &running);
        // burst decay: an expiry emission burst must not pin out_buf
        // capacity past this flush point (no-op in steady state)
        pool::shrink_excess(&mut out_buf, pool::DEFAULT_SHRINK_CAP);
        if moved || processed > 0 {
            backoff.reset();
        } else {
            backoff.snooze();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Key;

    /// Payload whose `Clone` bumps a shared counter — makes the fan-out
    /// copy count observable. The `Arc` bump in `clone` is bookkeeping,
    /// not the measured allocation.
    #[derive(Debug, Default)]
    struct Counted(Arc<AtomicU64>);

    impl Clone for Counted {
        fn clone(&self) -> Self {
            self.0.fetch_add(1, Ordering::Relaxed);
            Counted(self.0.clone())
        }
    }

    /// f_MK emits keys `0..fan` for every tuple: with a hash mapper this
    /// hits a deterministic subset of the instances.
    struct FanLogic {
        fan: u64,
    }

    impl OperatorLogic for FanLogic {
        type In = Counted;
        type Out = Counted;
        type State = ();
        fn keys(&self, _t: &Tuple<Counted>, keys: &mut Vec<Key>) {
            keys.extend(0..self.fan);
        }
        fn update(
            &self,
            _w: &mut crate::operator::WindowSet<()>,
            _t: &Tuple<Counted>,
            _ctx: &mut Ctx<'_, Counted>,
        ) {
        }
    }

    fn test_ingress(
        pi: usize,
        fan: u64,
        queue_cap: usize,
    ) -> (SnIngress<FanLogic>, Vec<Consumer<Tuple<Counted>>>) {
        let mut queues = Vec::with_capacity(pi);
        let mut consumers = Vec::with_capacity(pi);
        for _ in 0..pi {
            let (p, c) = spsc::spsc(queue_cap);
            queues.push(p);
            consumers.push(c);
        }
        let ing = SnIngress {
            logic: Arc::new(FanLogic { fan }),
            mapper: Mapper::hash_mod(pi),
            queues,
            keys_buf: Vec::new(),
            targets: vec![false; pi],
            staging: Vec::new(),
            forwarded: Arc::new(AtomicU64::new(0)),
            running: Arc::new(AtomicBool::new(true)),
        };
        (ing, consumers)
    }

    /// How many of the `pi` instances the keys `0..fan` actually hit
    /// under the ingress's own mapper (deterministic for fixed inputs).
    fn hit_count(ing: &SnIngress<FanLogic>, pi: usize, fan: u64) -> u64 {
        let mut hits = vec![false; pi];
        for k in 0..fan {
            hits[ing.mapper.map(k)] = true;
        }
        hits.iter().filter(|&&h| h).count() as u64
    }

    fn drain_all(consumers: &mut [Consumer<Tuple<Counted>>]) -> u64 {
        let mut scratch = Vec::new();
        let mut total = 0u64;
        for c in consumers.iter_mut() {
            while c.pop_chunk(&mut scratch, usize::MAX) > 0 {
                total += scratch.drain(..).count() as u64;
            }
        }
        total
    }

    #[test]
    fn forward_clones_exactly_hits_minus_one() {
        let (pi, fan) = (4, 64u64);
        let (mut ing, mut consumers) = test_ingress(pi, fan, 1 << 10);
        let hits = hit_count(&ing, pi, fan);
        assert!(hits >= 2, "need a multi-target tuple for the test to bite");
        let ctr = Arc::new(AtomicU64::new(0));
        ing.forward(Tuple::data(1, Counted(ctr.clone())));
        assert_eq!(
            ctr.load(Ordering::Relaxed),
            hits - 1,
            "n-target fan-out must clone exactly n − 1 times (last target takes the move)"
        );
        assert_eq!(drain_all(&mut consumers), hits, "every responsible instance got the tuple");
        assert_eq!(ing.forwarded.load(Ordering::Relaxed), hits);
    }

    #[test]
    fn forward_single_target_is_zero_copy() {
        // one key → one responsible instance → the original moves, no clone
        let (mut ing, mut consumers) = test_ingress(4, 1, 1 << 10);
        let ctr = Arc::new(AtomicU64::new(0));
        ing.forward(Tuple::data(1, Counted(ctr.clone())));
        assert_eq!(ctr.load(Ordering::Relaxed), 0, "single-target routing must not clone");
        assert_eq!(drain_all(&mut consumers), 1);
    }

    #[test]
    fn forward_broadcast_clones_exactly_pi_minus_one() {
        let pi = 3;
        let (mut ing, mut consumers) = test_ingress(pi, 1, 1 << 10);
        let ctr = Arc::new(AtomicU64::new(0));
        let mut hb: Tuple<Counted> = Tuple::heartbeat(7);
        hb.payload = Counted(ctr.clone());
        ing.forward(hb);
        assert_eq!(ctr.load(Ordering::Relaxed), (pi as u64) - 1, "broadcast clones Π − 1 times");
        assert_eq!(drain_all(&mut consumers), pi as u64);
    }

    #[test]
    fn forward_batch_clones_exactly_hits_minus_one_per_tuple() {
        let (pi, fan) = (4, 64u64);
        let (mut ing, mut consumers) = test_ingress(pi, fan, 1 << 10);
        let hits = hit_count(&ing, pi, fan);
        assert!(hits >= 2);
        let ctr = Arc::new(AtomicU64::new(0));
        let n = 10u64;
        let mut run: Vec<Tuple<Counted>> =
            (1..=n).map(|ts| Tuple::data(ts as i64, Counted(ctr.clone()))).collect();
        ing.forward_batch(&mut run);
        assert!(run.is_empty(), "forward_batch drains the run");
        assert_eq!(
            ctr.load(Ordering::Relaxed),
            n * (hits - 1),
            "batched fan-out must clone exactly n − 1 per tuple"
        );
        assert_eq!(drain_all(&mut consumers), n * hits);
        assert_eq!(ing.forwarded.load(Ordering::Relaxed), n * hits);
    }

    #[test]
    fn staging_rows_decay_after_a_burst() {
        // queues sized to absorb the whole burst in one flush, so the
        // single-threaded test never blocks on backpressure
        let n = 2 * pool::DEFAULT_SHRINK_CAP;
        let (mut ing, mut consumers) = test_ingress(2, 64, 4 * pool::DEFAULT_SHRINK_CAP);
        let ctr = Arc::new(AtomicU64::new(0));
        let mut run: Vec<Tuple<Counted>> =
            (1..=n).map(|ts| Tuple::data(ts as i64, Counted(ctr.clone()))).collect();
        // one hot run inflates the staging rows well past the cap...
        ing.forward_batch(&mut run);
        // ...and the post-flush decay must hand that capacity back
        for row in &ing.staging {
            assert!(
                row.capacity() <= pool::DEFAULT_SHRINK_CAP,
                "staging row pins {} capacity past the shrink cap",
                row.capacity()
            );
        }
        drain_all(&mut consumers);
    }
}
