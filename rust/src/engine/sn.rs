//! The shared-nothing baseline engine (§2.2, Alg. 1 + Alg. 2).
//!
//! This is the paper's SN model — the "Flink-like" comparison system of
//! §8: each ⟨upstream, instance⟩ pair exchanges tuples over a *dedicated*
//! queue; `forwardSN` routes a tuple to every instance responsible for at
//! least one of its keys (cloning it — the Theorem-1 data duplication);
//! each instance merge-sorts its input queues (implicit watermarks,
//! Def. 3) and runs `processSN` over its *private* state. The egress
//! merge-sorts the instances' outputs, as the paper assumes for
//! order-sensitive analysis (§8).

use crate::engine::vsn::EngineClock;
use crate::metrics::{Histogram, OperatorMetrics};
use crate::operator::state::SharedState;
use crate::operator::{Ctx, OperatorCore, OperatorDef, OperatorLogic};
use crate::tuple::{Mapper, Tuple};
use crate::util::spsc::{self, Consumer, Producer, PushError};
use crate::util::Backoff;
use crate::watermark::MergeSorter;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// SN engine options.
#[derive(Clone, Debug)]
pub struct SnOptions {
    /// Π(O): number of operator instances.
    pub parallelism: usize,
    /// Number of upstream (ingress) instances running forwardSN.
    pub upstreams: usize,
    /// Capacity of each dedicated queue (backpressure bound).
    pub queue_capacity: usize,
}

impl Default for SnOptions {
    fn default() -> Self {
        SnOptions { parallelism: 1, upstreams: 1, queue_capacity: 1 << 12 }
    }
}

/// A running SN engine.
pub struct SnEngine<L: OperatorLogic> {
    pub metrics: Arc<OperatorMetrics>,
    _marker: std::marker::PhantomData<fn(L)>,
    /// Total enqueues performed by forwardSN — compare with tuples_in to
    /// quantify the duplication overhead (Theorem 1).
    pub forwarded: Arc<AtomicU64>,
    pub clock: EngineClock,
    pub mapper: Mapper,
    running: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Upstream endpoint: runs `forwardSN` (Alg. 1).
pub struct SnIngress<L: OperatorLogic> {
    logic: Arc<L>,
    mapper: Mapper,
    queues: Vec<Producer<Tuple<L::In>>>,
    keys_buf: Vec<crate::tuple::Key>,
    targets: Vec<bool>,
    forwarded: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl<L: OperatorLogic> SnIngress<L> {
    /// forwardSN: route `t` to every instance responsible for one of its
    /// keys (cloning per target); heartbeats broadcast to all instances.
    pub fn forward(&mut self, t: Tuple<L::In>) {
        if !t.kind.is_data() {
            for q in self.queues.iter_mut() {
                push_blocking(q, t.clone(), &self.running);
            }
            return;
        }
        self.keys_buf.clear();
        self.logic.keys(&t, &mut self.keys_buf);
        self.targets.iter_mut().for_each(|x| *x = false);
        for &k in &self.keys_buf {
            self.targets[self.mapper.map(k)] = true;
        }
        let mut n = 0;
        for (j, &hit) in self.targets.iter().enumerate() {
            if hit {
                push_blocking(&mut self.queues[j], t.clone(), &self.running);
                n += 1;
            }
        }
        self.forwarded.fetch_add(n, Ordering::Relaxed);
    }

    /// Advance all downstream channels when this upstream idles.
    pub fn heartbeat(&mut self, ts: crate::time::EventTime)
    where
        L::In: Default,
    {
        self.forward(Tuple::heartbeat(ts));
    }
}

fn push_blocking<T>(q: &mut Producer<T>, mut v: T, running: &AtomicBool) {
    let mut b = Backoff::active();
    loop {
        match q.try_push(v) {
            Ok(()) => return,
            Err(PushError::Closed(_)) => return,
            Err(PushError::Full(back)) => {
                if !running.load(Ordering::Acquire) {
                    return;
                }
                v = back;
                b.snooze();
            }
        }
    }
}

/// Egress endpoint: merge-sorts the instances' output channels and
/// records throughput + latency (driven by the caller, like the paper's
/// sink).
pub struct SnEgress<Out: Clone + Send + Sync + 'static> {
    channels: Vec<Consumer<Tuple<Out>>>,
    sorter: MergeSorter<Out>,
    pub clock: EngineClock,
    pub count: u64,
    pub latency_us: Arc<Histogram>,
}

impl<Out: Clone + Send + Sync + 'static> SnEgress<Out> {
    /// Drain available output tuples; returns how many data tuples passed.
    pub fn poll(&mut self) -> usize {
        // pull everything available into the sorter
        for (ch, c) in self.channels.iter_mut().enumerate() {
            while let Some(t) = c.try_pop() {
                self.sorter.offer(ch, t);
            }
        }
        let mut n = 0;
        while let Some(t) = self.sorter.pop_ready() {
            if t.kind.is_data() {
                self.count += 1;
                n += 1;
                if t.ingest_us > 0 {
                    let now = self.clock.now_us();
                    self.latency_us.record(now.saturating_sub(t.ingest_us));
                }
            }
        }
        n
    }

    pub fn drain_until(&mut self, expected: u64, timeout: std::time::Duration) -> u64 {
        let t0 = std::time::Instant::now();
        let mut backoff = Backoff::active();
        while self.count < expected && t0.elapsed() < timeout {
            if self.poll() == 0 {
                backoff.snooze();
            } else {
                backoff.reset();
            }
        }
        self.count
    }

    /// Like [`poll`](Self::poll) but hands every ready data tuple to `f`.
    pub fn poll_tuples(&mut self, f: &mut dyn FnMut(&Tuple<Out>)) -> usize {
        for (ch, c) in self.channels.iter_mut().enumerate() {
            while let Some(t) = c.try_pop() {
                self.sorter.offer(ch, t);
            }
        }
        let mut n = 0;
        while let Some(t) = self.sorter.pop_ready() {
            if t.kind.is_data() {
                self.count += 1;
                n += 1;
                if t.ingest_us > 0 {
                    let now = self.clock.now_us();
                    self.latency_us.record(now.saturating_sub(t.ingest_us));
                }
                f(&t);
            }
        }
        n
    }
}

impl<L: OperatorLogic> SnEngine<L>
where
    L::In: Default,
    L::Out: Default,
{
    /// Build the SN topology: `upstreams × parallelism` dedicated input
    /// queues, one instance thread per o_j with private state, and a
    /// caller-driven egress.
    pub fn setup(
        def: OperatorDef<L>,
        opts: SnOptions,
    ) -> (Self, Vec<SnIngress<L>>, SnEgress<L::Out>) {
        let pi = opts.parallelism;
        let u = opts.upstreams;
        assert!(pi >= 1 && u >= 1);
        let mapper = Mapper::hash_mod(pi);
        let metrics = OperatorMetrics::new(pi);
        let forwarded = Arc::new(AtomicU64::new(0));
        let running = Arc::new(AtomicBool::new(true));
        let clock = EngineClock::new();

        // queues[u][j]
        let mut ingress_producers: Vec<Vec<Producer<Tuple<L::In>>>> =
            (0..u).map(|_| Vec::with_capacity(pi)).collect();
        let mut instance_consumers: Vec<Vec<Consumer<Tuple<L::In>>>> =
            (0..pi).map(|_| Vec::with_capacity(u)).collect();
        for uu in 0..u {
            for jj in 0..pi {
                let (p, c) = spsc::spsc(opts.queue_capacity);
                ingress_producers[uu].push(p);
                instance_consumers[jj].push(c);
            }
        }
        // egress channels [j]
        let mut egress_producers = Vec::with_capacity(pi);
        let mut egress_consumers = Vec::with_capacity(pi);
        for _ in 0..pi {
            let (p, c) = spsc::spsc::<Tuple<L::Out>>(opts.queue_capacity);
            egress_producers.push(p);
            egress_consumers.push(c);
        }

        let mut threads = Vec::with_capacity(pi);
        for (j, (consumers, mut egress)) in
            instance_consumers.into_iter().zip(egress_producers).enumerate()
        {
            let def = def.clone();
            let metrics = metrics.clone();
            let mapper = mapper.clone();
            let running = running.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-sn-{j}", def.name))
                    .spawn(move || {
                        run_instance::<L>(def, j, consumers, &mut egress, mapper, metrics, running)
                    })
                    .expect("spawn sn instance"),
            );
        }

        let ingress = ingress_producers
            .into_iter()
            .map(|queues| SnIngress {
                logic: def.logic.clone(),
                mapper: mapper.clone(),
                targets: vec![false; pi],
                queues,
                keys_buf: Vec::with_capacity(16),
                forwarded: forwarded.clone(),
                running: running.clone(),
            })
            .collect();

        let egress = SnEgress {
            sorter: MergeSorter::new(pi),
            channels: egress_consumers,
            clock: clock.clone(),
            count: 0,
            latency_us: Arc::new(Histogram::new()),
        };

        (
            SnEngine { metrics, forwarded, clock, mapper, running, threads, _marker: std::marker::PhantomData },
            ingress,
            egress,
        )
    }

    pub fn shutdown(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl<L: OperatorLogic> Drop for SnEngine<L> {
    fn drop(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One SN instance thread: merge-sort dedicated queues, processSN, forward
/// outputs (plus watermark heartbeats) to the egress channel.
fn run_instance<L: OperatorLogic>(
    def: OperatorDef<L>,
    j: usize,
    mut consumers: Vec<Consumer<Tuple<L::In>>>,
    egress: &mut Producer<Tuple<L::Out>>,
    mapper: Mapper,
    metrics: Arc<OperatorMetrics>,
    running: Arc<AtomicBool>,
) where
    L::Out: Default,
{
    let mut core: OperatorCore<L> = OperatorCore::new(def, j, SharedState::private(), metrics.clone());
    let mut sorter: MergeSorter<L::In> = MergeSorter::new(consumers.len());
    let mut backoff = Backoff::pooled();
    let mut last_emitted = crate::time::TIME_MIN;
    while running.load(Ordering::Acquire) {
        // intake
        let mut moved = false;
        for (ch, c) in consumers.iter_mut().enumerate() {
            while let Some(t) = c.try_pop() {
                sorter.offer(ch, t);
                moved = true;
            }
        }
        // process ready tuples
        let mut processed = 0u32;
        let mut drained = true;
        while let Some(t) = sorter.pop_ready() {
            processed += 1;
            let grew = core.observe(t.ts);
            let mut emitted = 0u64;
            {
                let running = &running;
                let last = &mut last_emitted;
                let mut sink = |o: Tuple<L::Out>| {
                    emitted += 1;
                    *last = (*last).max(o.ts);
                    push_blocking(egress, o, running);
                };
                let mut ctx = Ctx::new(&mut sink);
                ctx.ingest_us = t.ingest_us;
                if grew {
                    core.advance(&mapper, &mut ctx);
                }
                if t.kind.is_data() {
                    core.handle_input(&t, &mapper, &mut ctx);
                    core.metrics.record_in(j);
                }
                if ctx.comparisons > 0 {
                    core.metrics.record_comparisons(ctx.comparisons);
                }
            }
            if emitted > 0 {
                core.metrics.record_out(emitted);
            }
            if grew && emitted == 0 {
                // watermark heartbeat so the egress sorter can progress;
                // never below anything already emitted (channel sortedness)
                let hb_ts = core.watermark().max(last_emitted);
                push_blocking(egress, Tuple::heartbeat(hb_ts), &running);
                last_emitted = hb_ts;
            }
            if processed > 256 {
                drained = false;
                break; // fairness: intake again
            }
        }
        // Heartbeats advance channel clocks without being queued by the
        // sorter; fold the combined watermark into the core so windows
        // expire when rates drop to zero (explicit watermarks, §2.3).
        // ONLY once every ready tuple has been processed — folding early
        // would expire windows ahead of their contributors.
        let wm = sorter.watermark();
        if drained && wm > core.watermark() && core.observe(wm) {
            let mut emitted = 0u64;
            {
                let running = &running;
                let last = &mut last_emitted;
                let mut sink = |o: Tuple<L::Out>| {
                    emitted += 1;
                    *last = (*last).max(o.ts);
                    push_blocking(egress, o, running);
                };
                let mut ctx = Ctx::new(&mut sink);
                core.advance(&mapper, &mut ctx);
            }
            if emitted > 0 {
                core.metrics.record_out(emitted);
            }
            let hb_ts = core.watermark().max(last_emitted);
            push_blocking(egress, Tuple::heartbeat(hb_ts), &running);
            last_emitted = hb_ts;
        }
        if moved || processed > 0 {
            backoff.reset();
        } else {
            backoff.snooze();
        }
    }
}
