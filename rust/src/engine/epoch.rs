//! Epoch state shared by all `O+` instances (Cond. 2, §5).
//!
//! An epoch is the event-time span between two reconfigurations during
//! which the key→instance mapping f_μ is fixed. The *current* epoch
//! config (e, 𝕆, f_μ) lives here; the *next* epoch parameters
//! (e*, 𝕆*, f_μ*, γ) are instance-local (Alg. 4 L3-6) and are set by
//! `prepareReconfig` from control tuples.

use crate::tuple::{Epoch, InstanceId, Mapper, ReconfigSpec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Immutable snapshot of one epoch's configuration.
#[derive(Clone, Debug)]
pub struct EpochConfig {
    pub epoch: Epoch,
    pub instances: Arc<Vec<InstanceId>>,
    pub mapper: Mapper,
}

impl EpochConfig {
    pub fn degree(&self) -> usize {
        self.instances.len()
    }
}

/// Shared holder of the current epoch config. Installation is idempotent:
/// every instance leaving the barrier installs the same (e*, 𝕆*, f_μ*);
/// only the first actually swaps.
pub struct EpochState {
    epoch_no: AtomicU64,
    current: Mutex<Arc<EpochConfig>>,
}

impl EpochState {
    pub fn new(initial: EpochConfig) -> Arc<Self> {
        Arc::new(EpochState {
            epoch_no: AtomicU64::new(initial.epoch),
            current: Mutex::new(Arc::new(initial)),
        })
    }

    /// Cheap staleness check for cached configs (one atomic load).
    ///
    /// ORDERING: Acquire pairs with `install`'s Release store — a worker
    /// that observes epoch e here will take the `current` lock and find a
    /// config at least as new as e (the store happens under that lock).
    #[inline]
    pub fn epoch_no(&self) -> Epoch {
        self.epoch_no.load(Ordering::Acquire)
    }

    /// Current config snapshot.
    pub fn current(&self) -> Arc<EpochConfig> {
        self.current.lock().unwrap().clone()
    }

    /// Install a new epoch (monotone; duplicate installs are no-ops).
    pub fn install(&self, spec: &ReconfigSpec) -> Arc<EpochConfig> {
        let mut cur = self.current.lock().unwrap();
        if spec.epoch > cur.epoch {
            *cur = Arc::new(EpochConfig {
                epoch: spec.epoch,
                instances: spec.instances.clone(),
                mapper: spec.mapper.clone(),
            });
            // ORDERING: Release pairs with `epoch_no()`'s Acquire; stored
            // under the `current` lock AFTER the config swap, so the
            // staleness check never runs ahead of the installed config.
            self.epoch_no.store(spec.epoch, Ordering::Release);
        }
        cur.clone()
    }
}

/// Instance-local pending reconfiguration (e*, 𝕆*, f_μ*, γ — Alg. 4 L3-6).
#[derive(Clone, Debug)]
pub struct PendingReconfig {
    pub spec: Arc<ReconfigSpec>,
    /// γ: the event time beyond which the switch triggers (the control
    /// tuple's timestamp, Alg. 6 L6).
    pub gamma: crate::time::EventTime,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Mapper;

    fn spec(e: Epoch, n: usize) -> ReconfigSpec {
        ReconfigSpec {
            epoch: e,
            instances: Arc::new((0..n).collect()),
            mapper: Mapper::hash_mod(n),
        }
    }

    #[test]
    fn install_is_monotone_and_idempotent() {
        let st = EpochState::new(EpochConfig {
            epoch: 0,
            instances: Arc::new(vec![0, 1]),
            mapper: Mapper::hash_mod(2),
        });
        assert_eq!(st.epoch_no(), 0);
        let c = st.install(&spec(1, 3));
        assert_eq!(c.epoch, 1);
        assert_eq!(c.degree(), 3);
        // duplicate install: no change
        let c2 = st.install(&spec(1, 3));
        assert_eq!(c2.epoch, 1);
        // stale install ignored
        let c3 = st.install(&spec(0, 9));
        assert_eq!(c3.epoch, 1);
        assert_eq!(st.epoch_no(), 1);
    }

    #[test]
    fn concurrent_installs_converge() {
        let st = EpochState::new(EpochConfig {
            epoch: 0,
            instances: Arc::new(vec![0]),
            mapper: Mapper::hash_mod(1),
        });
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let st = st.clone();
                std::thread::spawn(move || st.install(&spec(1, 5)).epoch)
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 1);
        }
        assert_eq!(st.current().degree(), 5);
    }
}
