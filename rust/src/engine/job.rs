//! The declarative JobSpec layer: a topology as *configuration*, not
//! code.
//!
//! STRETCH's pitch is that VSN keeps the widely-adopted SN-style APIs
//! while the runtime handles scale-up and sub-40 ms reconfiguration —
//! and in engines people actually adopt, a job is a *declaration* the
//! engine plans (Flink jobs, Elasticutor's executor model, the
//! parallelization plans of Röger & Mayer's survey), not bespoke wiring
//! in the host language. This module closes that gap: a `[topology]` /
//! `[stage.<name>]` config (parsed by [`crate::config::Config`])
//! declares stages by name, edges, per-stage parallelism and operator
//! parameters; [`JobSpec::from_config`] validates it (unknown operator,
//! dangling edge, cycle, edge payload-type mismatch → typed
//! [`JobError`]s) and [`JobSpec::build`] resolves every stage through
//! the operator registry ([`crate::workloads::registry`]) into ONE
//! [`DagBuilder`] pass — the same construction path the typed
//! [`crate::engine::pipeline::PipelineBuilder`] and hand-built DAGs use,
//! so a config-built topology is gate-for-gate identical to a hand-built
//! one.
//!
//! ```text
//! [topology]
//! stages = ["filter", "left", "right", "join"]
//!
//! [stage.filter]
//! operator = "trade-filter"
//! max = 2
//!
//! [stage.left]
//! operator = "left-leg"
//! inputs = ["filter"]          # or: [topology] edges = ["filter -> left"]
//! ...
//! ```
//!
//! Stage order in the config is free — stages are topologically sorted
//! before building (sources first), and [`BuiltJob::stage_names`] maps
//! the running pipeline's stage indices back to config names. Driving a
//! job under a rate schedule (controllers, adaptive batching,
//! `BENCH_<job>.json`) lives in [`crate::harness::run_job`]; the
//! `stretch run --config job.conf` CLI entrypoint wraps that.

use crate::config::{Config, ConfigError, ConfigValue};
use crate::engine::dag::{DagBuilder, DagError, NodeHandle};
use crate::engine::pipeline::Pipeline;
use crate::engine::vsn::VsnOptions;
use crate::harness::HarnessError;
use crate::runtime::placement::{CoreMap, PlacementError, PlacementPlan, StageRequest};
use crate::workloads::registry::{self, JobPayload, PayloadKind, StageParams};
use std::collections::BTreeMap;
use std::fmt;

/// Typed errors of the declarative job layer — every way a config can be
/// wrong is reported by name, before any thread or gate exists.
#[derive(Debug)]
pub enum JobError {
    /// The config file failed to load/parse.
    Config(ConfigError),
    /// `[topology] stages` is missing or empty.
    NoStages,
    /// The same stage name is declared twice.
    DuplicateStage(String),
    /// A stage names an operator the registry does not know.
    UnknownOperator { stage: String, operator: String },
    /// An edge references an undeclared stage.
    DanglingEdge { stage: String, input: String },
    /// The same edge is declared twice (via `inputs` and/or `edges`).
    DuplicateEdge { stage: String, input: String },
    /// The edges contain a cycle through this stage.
    Cycle { stage: String },
    /// An edge's upstream output payload kind does not match the
    /// consumer's input kind.
    TypeMismatch {
        stage: String,
        input: String,
        expected: PayloadKind,
        got: PayloadKind,
    },
    /// Source stages disagree on the external input payload kind (one
    /// paced generator feeds every ingress).
    MixedSourceKinds {
        first: PayloadKind,
        stage: String,
        got: PayloadKind,
    },
    /// A payload-polymorphic operator (`forward`) was declared as a
    /// source stage — with no upstream there is nothing to infer its
    /// payload kind from.
    PolymorphicSource { stage: String, operator: String },
    /// No paced generator produces this payload kind (the job can still
    /// be built and fed manually — only `run_job` needs a generator).
    NoSource(PayloadKind),
    /// A key exists but its value is out of range / of the wrong type.
    BadValue { key: String, msg: String },
    /// The declared topology failed DAG validation (fan-out set
    /// conflicts and friends).
    Dag(DagError),
    /// The built job could not be driven (degenerate ingress/egress).
    Harness(HarnessError),
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::Config(e) => write!(f, "config: {e}"),
            JobError::NoStages => {
                write!(f, "`[topology] stages` is missing or empty — nothing to build")
            }
            JobError::DuplicateStage(s) => write!(f, "stage `{s}` declared twice"),
            JobError::UnknownOperator { stage, operator } => write!(
                f,
                "stage `{stage}`: unknown operator `{operator}` (known: {})",
                registry::known_operators().join(", ")
            ),
            JobError::DanglingEdge { stage, input } => write!(
                f,
                "stage `{stage}` consumes `{input}`, which is not a declared stage"
            ),
            JobError::DuplicateEdge { stage, input } => {
                write!(f, "edge `{input}` -> `{stage}` declared twice")
            }
            JobError::Cycle { stage } => write!(
                f,
                "topology has a cycle through stage `{stage}` — jobs must be DAGs"
            ),
            JobError::TypeMismatch { stage, input, expected, got } => write!(
                f,
                "stage `{stage}` consumes `{expected}` but upstream `{input}` produces `{got}`"
            ),
            JobError::MixedSourceKinds { first, stage, got } => write!(
                f,
                "source stages disagree on the external payload kind: \
                 saw `{first}`, but `{stage}` consumes `{got}`"
            ),
            JobError::PolymorphicSource { stage, operator } => write!(
                f,
                "stage `{stage}`: operator `{operator}` adapts to its upstream's payload \
                 kind, so it cannot be a source stage (give it an input)"
            ),
            JobError::NoSource(kind) => {
                write!(f, "no paced generator produces payload kind `{kind}`")
            }
            JobError::BadValue { key, msg } => write!(f, "key `{key}`: {msg}"),
            JobError::Dag(e) => write!(f, "topology: {e}"),
            JobError::Harness(e) => write!(f, "harness: {e}"),
        }
    }
}

impl std::error::Error for JobError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            JobError::Config(e) => Some(e),
            JobError::Dag(e) => Some(e),
            JobError::Harness(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for JobError {
    fn from(e: ConfigError) -> Self {
        JobError::Config(e)
    }
}

impl From<DagError> for JobError {
    fn from(e: DagError) -> Self {
        JobError::Dag(e)
    }
}

/// One declared stage, fully resolved against the config defaults.
#[derive(Clone, Debug)]
pub struct StageSpec {
    pub name: String,
    /// Registry operator name (validated to exist).
    pub operator: String,
    /// Upstream stage names (empty ⇔ external source stage).
    pub inputs: Vec<String>,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    pub gate_capacity: usize,
    pub worker_batch: usize,
    /// External ingress wrappers (source stages only).
    pub upstreams: usize,
    /// Egress reader ends (sink stages only).
    pub egress_readers: usize,
    /// Explicit kernel core ids for this stage's workers (`cores = [..]`
    /// in `[stage.<name>]`) — validated against the machine's
    /// [`CoreMap`] when a placement plan is computed.
    pub cores: Vec<usize>,
    /// Explicit socket index for this stage (`socket = N`).
    pub socket: Option<usize>,
    /// Operator parameters (`ws_ms`, `wa_ms`, `lb_keys`, `keys`).
    pub params: StageParams,
}

/// A validated, topologically ordered job declaration.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    /// Stages in topological order (sources first) — the order their
    /// engines are built and the order of `Pipeline::stages`.
    pub stages: Vec<StageSpec>,
    /// External input payload kind every source stage consumes.
    pub source_kind: PayloadKind,
    /// Sink stage names (stages nothing consumes), topological order.
    pub sinks: Vec<String>,
}

/// A running, config-built topology plus the name map back into the
/// config's stage names.
pub struct BuiltJob {
    pub pipeline: Pipeline<JobPayload, JobPayload>,
    /// Config stage names aligned with `pipeline.stages` indices.
    pub stage_names: Vec<String>,
}

impl BuiltJob {
    /// Stage index of a config stage name (for `reconfigure_stage`).
    pub fn stage_index(&self, name: &str) -> Option<usize> {
        self.stage_names.iter().position(|n| n == name)
    }
}

fn int_field(c: &Config, key: String, default: i64) -> Result<i64, JobError> {
    match c.get(&key) {
        None => Ok(default),
        Some(ConfigValue::Int(v)) => Ok(*v),
        Some(other) => Err(JobError::BadValue {
            key,
            msg: format!("expected an integer, got `{other}`"),
        }),
    }
}

fn positive(key: String, v: i64) -> Result<usize, JobError> {
    if v >= 1 {
        Ok(v as usize)
    } else {
        Err(JobError::BadValue { key, msg: format!("must be ≥ 1, got {v}") })
    }
}

/// Read an optional list of kernel core ids (`cores = [0, 4]`); absent →
/// empty. Core ids must be ≥ 0 — existence on THIS machine is checked
/// later, against a [`CoreMap`], so parse errors stay machine-independent.
fn core_list(c: &Config, key: String) -> Result<Vec<usize>, JobError> {
    match c.get(&key) {
        None => Ok(Vec::new()),
        Some(ConfigValue::List(xs)) => xs
            .iter()
            .map(|x| match x {
                ConfigValue::Int(v) if *v >= 0 => Ok(*v as usize),
                other => Err(JobError::BadValue {
                    key: key.clone(),
                    msg: format!("expected a core id ≥ 0, got `{other}`"),
                }),
            })
            .collect(),
        Some(other) => Err(JobError::BadValue {
            key,
            msg: format!("expected a list of core ids, got `{other}`"),
        }),
    }
}

/// Read a list-of-strings key (shared with the harness's
/// `[schedule.<stage>]` parsing).
pub(crate) fn string_list(c: &Config, key: &str) -> Result<Option<Vec<String>>, JobError> {
    match c.get(key) {
        None => Ok(None),
        Some(ConfigValue::List(xs)) => xs
            .iter()
            .map(|x| match x {
                ConfigValue::Str(s) => Ok(s.clone()),
                other => Err(JobError::BadValue {
                    key: key.to_string(),
                    msg: format!("expected a string list element, got `{other}`"),
                }),
            })
            .collect::<Result<Vec<_>, _>>()
            .map(Some),
        Some(other) => Err(JobError::BadValue {
            key: key.to_string(),
            msg: format!("expected a list, got `{other}`"),
        }),
    }
}

impl JobSpec {
    /// Parse and validate a job declaration from a config. Every failure
    /// mode is a typed [`JobError`]; nothing is spawned here.
    pub fn from_config(c: &Config) -> Result<JobSpec, JobError> {
        let stage_names = match string_list(c, "topology.stages")? {
            Some(v) if !v.is_empty() => v,
            _ => return Err(JobError::NoStages),
        };
        for (i, n) in stage_names.iter().enumerate() {
            if stage_names[..i].contains(n) {
                return Err(JobError::DuplicateStage(n.clone()));
            }
            if n.is_empty() || !n.chars().all(|ch| ch.is_ascii_alphanumeric() || ch == '-' || ch == '_') {
                return Err(JobError::BadValue {
                    key: "topology.stages".into(),
                    msg: format!(
                        "stage name `{n}` must be non-empty [A-Za-z0-9_-] \
                         (it becomes a `[stage.<name>]` section key)"
                    ),
                });
            }
        }

        // Reject unknown `[topology]` / `[stage.*]` keys up front: a
        // typo'd key (e.g. `window_ms` for `ws_ms`) silently falling
        // back to a default would run a different job than the one the
        // user declared — the opposite of this layer's contract.
        const STAGE_KEYS: &[&str] = &[
            "operator",
            "inputs",
            "initial",
            "max",
            "gate_capacity",
            "worker_batch",
            "upstreams",
            "egress_readers",
            "cores",
            "socket",
            "ws_ms",
            "wa_ms",
            "lb_keys",
            "keys",
            "pair_bound",
        ];
        for k in c.keys() {
            if let Some(rest) = k.strip_prefix("topology.") {
                if rest != "stages" && rest != "edges" {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: "unknown `[topology]` key (expected `stages` or `edges`)".into(),
                    });
                }
            } else if let Some(rest) = k.strip_prefix("stage.") {
                let (stage, field) = rest.split_once('.').ok_or_else(|| JobError::BadValue {
                    key: k.to_string(),
                    msg: "expected `stage.<name>.<field>`".into(),
                })?;
                if !stage_names.iter().any(|n| n == stage) {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!(
                            "section `[stage.{stage}]` does not match any declared stage \
                             (declared: {})",
                            stage_names.join(", ")
                        ),
                    });
                }
                if !STAGE_KEYS.contains(&field) {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!("unknown stage key `{field}` (known: {})", STAGE_KEYS.join(", ")),
                    });
                }
            }
        }

        let default_batch = crate::config::BatchTuning::from_config(c).worker;
        let mut stages: Vec<StageSpec> = Vec::with_capacity(stage_names.len());
        for n in &stage_names {
            let key = |k: &str| format!("stage.{n}.{k}");
            let operator = match c.get(&key("operator")) {
                Some(ConfigValue::Str(s)) => s.clone(),
                Some(other) => {
                    return Err(JobError::BadValue {
                        key: key("operator"),
                        msg: format!("expected an operator name string, got `{other}`"),
                    })
                }
                None => {
                    return Err(JobError::BadValue {
                        key: key("operator"),
                        msg: "every stage needs an `operator = \"...\"`".into(),
                    })
                }
            };
            if registry::resolve(&operator).is_none() {
                return Err(JobError::UnknownOperator { stage: n.clone(), operator });
            }
            let inputs = string_list(c, &key("inputs"))?.unwrap_or_default();
            let initial = positive(key("initial"), int_field(c, key("initial"), 1)?)?;
            let max = positive(key("max"), int_field(c, key("max"), 4)?)?;
            if initial > max {
                return Err(JobError::BadValue {
                    key: key("initial"),
                    msg: format!("initial parallelism {initial} exceeds max {max}"),
                });
            }
            let ws_ms = positive(key("ws_ms"), int_field(c, key("ws_ms"), 1_000)?)? as i64;
            let wa_ms = positive(key("wa_ms"), int_field(c, key("wa_ms"), ws_ms)?)? as i64;
            let cores = core_list(c, key("cores"))?;
            let socket = match c.get(&key("socket")) {
                None => None,
                Some(ConfigValue::Int(v)) if *v >= 0 => Some(*v as usize),
                Some(other) => {
                    return Err(JobError::BadValue {
                        key: key("socket"),
                        msg: format!("expected a socket index ≥ 0, got `{other}`"),
                    })
                }
            };
            stages.push(StageSpec {
                name: n.clone(),
                operator,
                inputs,
                initial,
                max,
                gate_capacity: positive(
                    key("gate_capacity"),
                    int_field(c, key("gate_capacity"), 1 << 15)?,
                )?,
                worker_batch: positive(
                    key("worker_batch"),
                    int_field(c, key("worker_batch"), default_batch as i64)?,
                )?,
                upstreams: positive(key("upstreams"), int_field(c, key("upstreams"), 1)?)?,
                egress_readers: positive(
                    key("egress_readers"),
                    int_field(c, key("egress_readers"), 1)?,
                )?,
                cores,
                socket,
                params: StageParams {
                    ws_ms,
                    wa_ms,
                    lb_keys: positive(key("lb_keys"), int_field(c, key("lb_keys"), 64)?)? as u64,
                    n_keys: positive(key("keys"), int_field(c, key("keys"), 32)?)? as u64,
                    pair_bound: positive(key("pair_bound"), int_field(c, key("pair_bound"), 10)?)?,
                },
            });
        }

        // `[topology] edges = ["a -> b", ...]` is sugar for per-stage
        // `inputs`; both merge (edge list appended in declaration order).
        // Keyed off `stage_names` (same order as `stages`) so the map's
        // borrows don't alias the mutable edge-merging below.
        let idx_of: BTreeMap<&str, usize> =
            stage_names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        if let Some(edges) = string_list(c, "topology.edges")? {
            for e in &edges {
                let (from, to) = e.split_once("->").ok_or_else(|| JobError::BadValue {
                    key: "topology.edges".into(),
                    msg: format!("expected `from -> to`, got `{e}`"),
                })?;
                let (from, to) = (from.trim().to_string(), to.trim().to_string());
                let Some(&ti) = idx_of.get(to.as_str()) else {
                    return Err(JobError::DanglingEdge { stage: to, input: from });
                };
                stages[ti].inputs.push(from);
            }
        }

        // edge validation: dangling references, duplicates, self-loops
        for s in &stages {
            for (i, inp) in s.inputs.iter().enumerate() {
                if !idx_of.contains_key(inp.as_str()) {
                    return Err(JobError::DanglingEdge {
                        stage: s.name.clone(),
                        input: inp.clone(),
                    });
                }
                if s.inputs[..i].contains(inp) {
                    return Err(JobError::DuplicateEdge {
                        stage: s.name.clone(),
                        input: inp.clone(),
                    });
                }
                if inp == &s.name {
                    return Err(JobError::Cycle { stage: s.name.clone() });
                }
            }
        }

        // stable topological sort (Kahn): config order is free, engines
        // must be built sources-first; a stall means a cycle
        let n = stages.len();
        let mut placed = vec![false; n];
        let mut order: Vec<usize> = Vec::with_capacity(n);
        while order.len() < n {
            let mut progressed = false;
            for i in 0..n {
                if !placed[i] && stages[i].inputs.iter().all(|inp| placed[idx_of[inp.as_str()]]) {
                    placed[i] = true;
                    order.push(i);
                    progressed = true;
                }
            }
            if !progressed {
                let stuck = (0..n).find(|&i| !placed[i]).expect("unplaced stage exists");
                return Err(JobError::Cycle { stage: stages[stuck].name.clone() });
            }
        }

        // reorder topologically (sources first) before kind resolution,
        // so every upstream's kind is known when its consumer is visited
        let stages: Vec<StageSpec> = order.into_iter().map(|i| stages[i].clone()).collect();

        // edge payload-type checking against the registry, with kind
        // *resolution*: a fixed entry carries its kinds; a polymorphic
        // entry (`forward`, input/output = None) adapts to its upstream's
        // resolved output, so it can sit on any edge of the topology
        let pos_of: BTreeMap<&str, usize> =
            stages.iter().enumerate().map(|(i, s)| (s.name.as_str(), i)).collect();
        let mut res_in: Vec<PayloadKind> = Vec::with_capacity(stages.len());
        let mut res_out: Vec<PayloadKind> = Vec::with_capacity(stages.len());
        for s in &stages {
            let entry = registry::resolve(&s.operator).expect("validated above");
            let rin = match entry.input() {
                Some(k) => k,
                None => {
                    let Some(first) = s.inputs.first() else {
                        return Err(JobError::PolymorphicSource {
                            stage: s.name.clone(),
                            operator: s.operator.clone(),
                        });
                    };
                    res_out[pos_of[first.as_str()]]
                }
            };
            for inp in &s.inputs {
                let got = res_out[pos_of[inp.as_str()]];
                if got != rin {
                    return Err(JobError::TypeMismatch {
                        stage: s.name.clone(),
                        input: inp.clone(),
                        expected: rin,
                        got,
                    });
                }
            }
            res_in.push(rin);
            res_out.push(entry.output().unwrap_or(rin));
        }

        // external source kind: every source stage must agree (one paced
        // generator feeds all ingress wrappers)
        let mut source_kind: Option<PayloadKind> = None;
        for (i, s) in stages.iter().enumerate() {
            if !s.inputs.is_empty() {
                continue;
            }
            let kind = res_in[i];
            match source_kind {
                None => source_kind = Some(kind),
                Some(first) if first != kind => {
                    return Err(JobError::MixedSourceKinds {
                        first,
                        stage: s.name.clone(),
                        got: kind,
                    })
                }
                Some(_) => {}
            }
        }
        let source_kind = source_kind.expect("a DAG always has a source stage");

        // sinks: stages nothing consumes, in topological order
        let consumed: Vec<&String> = stages.iter().flat_map(|s| s.inputs.iter()).collect();
        let sinks: Vec<String> = stages
            .iter()
            .filter(|s| !consumed.iter().any(|c| *c == &s.name))
            .map(|s| s.name.clone())
            .collect();

        Ok(JobSpec {
            name: c.str_or("name", "job").to_string(),
            stages,
            source_kind,
            sinks,
        })
    }

    /// Map this job onto a machine: one [`StageRequest`] per stage in
    /// build order, workers = `max` (pooled instances are spawned during
    /// the same build and inherit the build thread's affinity mask, so
    /// every slot needs a core). Explicit `cores`/`socket` stage keys
    /// are validated against `map` here — a core id that parsed fine can
    /// still not exist on THIS machine.
    pub fn placement_plan(&self, map: &CoreMap) -> Result<PlacementPlan, JobError> {
        let pos: BTreeMap<&str, usize> =
            self.stages.iter().enumerate().map(|(i, s)| (s.name.as_str(), i)).collect();
        let reqs: Vec<StageRequest> = self
            .stages
            .iter()
            .map(|s| StageRequest {
                name: s.name.clone(),
                workers: s.max,
                cores: s.cores.clone(),
                socket: s.socket,
                upstreams: s.inputs.iter().map(|i| pos[i.as_str()]).collect(),
            })
            .collect();
        PlacementPlan::assign(map, &reqs).map_err(|e| {
            let key = match &e {
                PlacementError::UnknownCore { stage, .. } => format!("stage.{stage}.cores"),
                PlacementError::UnknownSocket { stage, .. } => format!("stage.{stage}.socket"),
            };
            JobError::BadValue { key, msg: e.to_string() }
        })
    }

    /// Resolve every stage through the operator registry and build the
    /// running topology — one [`DagBuilder`] pass, the same construction
    /// path hand-built topologies use.
    pub fn build(&self) -> Result<BuiltJob, JobError> {
        self.build_planned(None)
    }

    /// [`build`](Self::build), placing threads and gate memory per
    /// `plan` (from [`placement_plan`](Self::placement_plan)): each
    /// stage's workers self-pin to their planned cores, and the build
    /// runs each stage's spawn — including first-touch allocation of its
    /// gate slot/`Log` arrays — pinned to a core of the owning socket.
    pub fn build_planned(&self, plan: Option<&PlacementPlan>) -> Result<BuiltJob, JobError> {
        let mut b = DagBuilder::<JobPayload>::new();
        if let Some(p) = plan {
            debug_assert_eq!(p.stages.len(), self.stages.len(), "plan/spec stage mismatch");
            b.set_spawn_cores(p.stages.iter().map(|sp| Some(sp.touch_core)).collect());
        }
        let mut handles: BTreeMap<&str, NodeHandle<JobPayload>> = BTreeMap::new();
        for (i, s) in self.stages.iter().enumerate() {
            let entry = registry::resolve(&s.operator).expect("JobSpec is validated");
            let ups: Vec<NodeHandle<JobPayload>> =
                s.inputs.iter().map(|i| handles[i.as_str()]).collect();
            let opts = VsnOptions {
                initial: s.initial,
                max: s.max,
                upstreams: s.upstreams,
                egress_readers: s.egress_readers,
                gate_capacity: s.gate_capacity,
                worker_batch: s.worker_batch,
                worker_cores: plan
                    .map(|p| p.stages[i].worker_cores.clone())
                    .unwrap_or_default(),
                ..Default::default()
            };
            let h = entry.instantiate(&s.params, &mut b, opts, &ups);
            handles.insert(&s.name, h);
        }
        let sinks: Vec<NodeHandle<JobPayload>> =
            self.sinks.iter().map(|n| handles[n.as_str()]).collect();
        let pipeline = b.build(&sinks)?;
        Ok(BuiltJob {
            pipeline,
            stage_names: self.stages.iter().map(|s| s.name.clone()).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<JobSpec, JobError> {
        JobSpec::from_config(&Config::parse(text).unwrap())
    }

    const DIAMOND: &str = r#"
name = "diamond"
[topology]
stages = ["join", "left", "right", "filter"]   # deliberately NOT topo order
[stage.filter]
operator = "trade-filter"
max = 2
[stage.left]
operator = "left-leg"
inputs = ["filter"]
max = 2
[stage.right]
operator = "right-leg"
inputs = ["filter"]
initial = 2
max = 2
[stage.join]
operator = "hedge-join"
inputs = ["left", "right"]
ws_ms = 800
keys = 32
max = 3
"#;

    #[test]
    fn diamond_round_trip_topo_sorts_and_infers_kinds() {
        let spec = parse(DIAMOND).unwrap();
        assert_eq!(spec.name, "diamond");
        let names: Vec<&str> = spec.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names[0], "filter", "sources must sort first");
        assert_eq!(names.last().copied(), Some("join"));
        assert_eq!(spec.sinks, vec!["join"]);
        assert_eq!(spec.source_kind, PayloadKind::Trade);
        let join = spec.stages.iter().find(|s| s.name == "join").unwrap();
        assert_eq!(join.params.ws_ms, 800);
        assert_eq!(join.params.n_keys, 32);
        assert_eq!(join.inputs, vec!["left", "right"]);
    }

    #[test]
    fn edges_sugar_is_equivalent_to_inputs() {
        let spec = parse(
            r#"
[topology]
stages = ["a", "b"]
edges = ["a -> b"]
[stage.a]
operator = "tweet-tokenize"
[stage.b]
operator = "word-count"
"#,
        )
        .unwrap();
        assert_eq!(spec.stages[1].inputs, vec!["a"]);
        assert_eq!(spec.sinks, vec!["b"]);
        assert_eq!(spec.source_kind, PayloadKind::Tweet);
    }

    #[test]
    fn cycle_is_a_typed_error() {
        let err = parse(
            r#"
[topology]
stages = ["a", "b"]
[stage.a]
operator = "trade-filter"
inputs = ["b"]
[stage.b]
operator = "trade-filter"
inputs = ["a"]
"#,
        )
        .unwrap_err();
        assert!(matches!(err, JobError::Cycle { .. }), "{err}");
        // self-loop is a (degenerate) cycle too
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ninputs = [\"a\"]",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::Cycle { .. }), "{err}");
    }

    #[test]
    fn unknown_operator_is_a_typed_error() {
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"frobnicate\"",
        )
        .unwrap_err();
        match err {
            JobError::UnknownOperator { stage, operator } => {
                assert_eq!((stage.as_str(), operator.as_str()), ("a", "frobnicate"));
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn dangling_edge_is_a_typed_error() {
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ninputs = [\"ghost\"]",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::DanglingEdge { .. }), "{err}");
        // ...and via the edges sugar, in either position
        let err = parse(
            "[topology]\nstages = [\"a\"]\nedges = [\"a -> ghost\"]\n[stage.a]\noperator = \"trade-filter\"",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::DanglingEdge { .. }), "{err}");
    }

    #[test]
    fn edge_type_mismatch_is_a_typed_error() {
        let err = parse(
            r#"
[topology]
stages = ["a", "b"]
[stage.a]
operator = "trade-filter"
[stage.b]
operator = "word-count"     # consumes words, not trades
inputs = ["a"]
"#,
        )
        .unwrap_err();
        match err {
            JobError::TypeMismatch { expected, got, .. } => {
                assert_eq!((expected, got), (PayloadKind::Word, PayloadKind::Trade));
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn no_stages_duplicates_and_bad_values_are_typed_errors() {
        assert!(matches!(parse("x = 1").unwrap_err(), JobError::NoStages));
        assert!(matches!(parse("[topology]\nstages = []").unwrap_err(), JobError::NoStages));
        let err = parse("[topology]\nstages = [\"a\", \"a\"]").unwrap_err();
        assert!(matches!(err, JobError::DuplicateStage(_)), "{err}");
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ninitial = 0",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ninitial = 3\nmax = 2",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
        let err = parse("[topology]\nstages = [\"a\"]").unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "missing operator: {err}");
    }

    #[test]
    fn unknown_keys_are_typed_errors_not_silent_defaults() {
        // typo'd operator parameter: must not silently run ws_ms = 1000
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"hedge-join\"\nwindow_ms = 800",
        )
        .unwrap_err();
        match err {
            JobError::BadValue { key, .. } => assert_eq!(key, "stage.a.window_ms"),
            other => panic!("{other}"),
        }
        // section for an undeclared stage
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\n\
             [stage.b]\noperator = \"trade-filter\"",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
        // typo'd topology key
        let err = parse("[topology]\nstages = [\"a\"]\nedgez = [\"a -> a\"]\n[stage.a]\noperator = \"trade-filter\"")
            .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
    }

    #[test]
    fn duplicate_edge_is_a_typed_error() {
        let err = parse(
            r#"
[topology]
stages = ["a", "b"]
edges = ["a -> b"]
[stage.a]
operator = "trade-filter"
[stage.b]
operator = "trade-filter"
inputs = ["a"]
"#,
        )
        .unwrap_err();
        assert!(matches!(err, JobError::DuplicateEdge { .. }), "{err}");
    }

    #[test]
    fn forward_resolves_its_kind_from_the_upstream() {
        // trade-filter → forward → forward → left-leg: both forwards
        // resolve to the trade kind and the chain type-checks end to end
        let spec = parse(
            r#"
[topology]
stages = ["src", "fwd1", "fwd2", "leg"]
edges = ["src -> fwd1", "fwd1 -> fwd2", "fwd2 -> leg"]
[stage.src]
operator = "trade-filter"
[stage.fwd1]
operator = "forward"
[stage.fwd2]
operator = "forward"
[stage.leg]
operator = "left-leg"
"#,
        )
        .unwrap();
        assert_eq!(spec.source_kind, PayloadKind::Trade);
        assert_eq!(spec.sinks, vec!["leg"]);
        // ...and the resolved topology actually spawns
        let mut built = spec.build().unwrap();
        assert_eq!(built.pipeline.depth(), 4);
        built.pipeline.shutdown();
        // a forward after a word stream feeds a word consumer (kind
        // flows through), but a mismatched consumer is still rejected
        let err = parse(
            r#"
[topology]
stages = ["tok", "fwd", "join"]
edges = ["tok -> fwd", "fwd -> join"]
[stage.tok]
operator = "tweet-tokenize"
[stage.fwd]
operator = "forward"
[stage.join]
operator = "hedge-join"
"#,
        )
        .unwrap_err();
        match err {
            JobError::TypeMismatch { expected, got, .. } => {
                assert_eq!((expected, got), (PayloadKind::TradePair, PayloadKind::Word));
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn closure_registered_operator_builds_from_config() {
        use crate::tuple::Tuple;
        use crate::workloads::registry::{JobPayload, OperatorRegistry};
        OperatorRegistry::register_fn(
            "test-dyn-dup",
            |t: &Tuple<JobPayload>, emit: &mut dyn FnMut(JobPayload)| {
                emit(t.payload.clone());
                emit(t.payload.clone());
            },
        )
        .unwrap();
        // a config can now name the closure like any static operator,
        // and the polymorphic kind resolution flows through it
        let spec = parse(
            "[topology]\nstages = [\"src\", \"dup\"]\nedges = [\"src -> dup\"]\n\
             [stage.src]\noperator = \"trade-filter\"\n\
             [stage.dup]\noperator = \"test-dyn-dup\"",
        )
        .unwrap();
        assert_eq!(spec.source_kind, PayloadKind::Trade);
        assert_eq!(spec.sinks, vec!["dup"]);
        let mut built = spec.build().unwrap();
        assert_eq!(built.pipeline.depth(), 2);
        built.pipeline.shutdown();
        // closure operators are payload-polymorphic: no source stages
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"test-dyn-dup\"",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::PolymorphicSource { .. }), "{err}");
    }

    #[test]
    fn forward_as_a_source_stage_is_a_typed_error() {
        let err =
            parse("[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"forward\"").unwrap_err();
        match err {
            JobError::PolymorphicSource { stage, operator } => {
                assert_eq!((stage.as_str(), operator.as_str()), ("a", "forward"));
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn pair_count_stage_parses_its_bound() {
        let spec = parse(
            "[topology]\nstages = [\"pc\"]\n[stage.pc]\noperator = \"pair-count\"\n\
             ws_ms = 2000\npair_bound = 3",
        )
        .unwrap();
        assert_eq!(spec.source_kind, PayloadKind::Tweet);
        assert_eq!(spec.stages[0].params.pair_bound, 3);
        // bound must stay ≥ 1
        let err = parse(
            "[topology]\nstages = [\"pc\"]\n[stage.pc]\noperator = \"pair-count\"\npair_bound = 0",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
    }

    #[test]
    fn placement_keys_round_trip_and_plan_against_a_fixture_map() {
        let spec = parse(
            r#"
[topology]
stages = ["a", "b"]
edges = ["a -> b"]
[stage.a]
operator = "trade-filter"
max = 2
cores = [1, 0]
[stage.b]
operator = "left-leg"
max = 2
socket = 0
"#,
        )
        .unwrap();
        assert_eq!(spec.stages[0].cores, vec![1, 0]);
        assert_eq!(spec.stages[0].socket, None);
        assert_eq!(spec.stages[1].cores, Vec::<usize>::new());
        assert_eq!(spec.stages[1].socket, Some(0));
        let plan = spec.placement_plan(&CoreMap::flat(4)).unwrap();
        assert_eq!(plan.stages[0].worker_cores, vec![1, 0]);
        assert_eq!(plan.stages[1].socket, 0);
        assert!(plan.runtime_core.is_some());
    }

    #[test]
    fn negative_core_is_a_parse_time_error() {
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ncores = [-1]",
        )
        .unwrap_err();
        match err {
            JobError::BadValue { key, .. } => assert_eq!(key, "stage.a.cores"),
            other => panic!("{other}"),
        }
        let err = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\nsocket = -2",
        )
        .unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
    }

    #[test]
    fn nonexistent_core_fails_the_plan_not_the_parse() {
        let spec = parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"\ncores = [9]",
        )
        .unwrap();
        // parse accepts it (machine-independent)...
        assert_eq!(spec.stages[0].cores, vec![9]);
        // ...the plan against a 2-core machine rejects it by key
        let err = spec.placement_plan(&CoreMap::flat(2)).unwrap_err();
        match err {
            JobError::BadValue { key, msg } => {
                assert_eq!(key, "stage.a.cores");
                assert!(msg.contains("core 9"), "{msg}");
            }
            other => panic!("{other}"),
        }
        // ...and on a big-enough machine the same spec plans fine
        assert!(spec.placement_plan(&CoreMap::flat(16)).is_ok());
    }

    #[test]
    fn planned_build_spawns_with_pinned_workers() {
        // plan against the REAL machine map and build with it: threads
        // self-pin (no-op if the kernel rejects the mask) and the
        // topology still flows
        let spec = parse(DIAMOND).unwrap();
        let plan = spec.placement_plan(&CoreMap::discover()).unwrap();
        assert_eq!(plan.stages.len(), spec.stages.len());
        let mut built = spec.build_planned(Some(&plan)).unwrap();
        assert_eq!(built.pipeline.depth(), 4);
        built.pipeline.shutdown();
    }

    #[test]
    fn config_built_diamond_spawns_and_exposes_name_map() {
        let spec = parse(DIAMOND).unwrap();
        let mut built = spec.build().unwrap();
        assert_eq!(built.pipeline.depth(), 4);
        assert_eq!(built.pipeline.ingress.len(), 1);
        assert_eq!(built.pipeline.egress.len(), 1);
        assert_eq!(built.stage_index("filter"), Some(0));
        assert_eq!(built.stage_index("join"), Some(3));
        assert_eq!(built.stage_index("ghost"), None);
        // operator names surfaced on the type-erased handles
        assert_eq!(built.pipeline.stages[0].name(), "trade-filter");
        assert_eq!(built.pipeline.stages[3].name(), "hedge");
        built.pipeline.shutdown();
    }
}
