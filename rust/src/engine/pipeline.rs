//! Multi-stage VSN pipelines (§7: "STRETCH can be used to instantiate
//! many (connected) operators within a query ... the ESG_out of such an
//! upstream peer" acts as the downstream's ESG_in).
//!
//! A pipeline composes `source → stage₁ → … → stageₖ → sink` where stage
//! N's ESG_out **is** stage N+1's ESG_in: one shared gate, zero-copy
//! hand-off, no re-ingestion. Each stage keeps its own instance pool,
//! epoch protocol and [`ControlPlane`], so stages reconfigure
//! *independently* — elasticity is a per-operator property of the
//! topology (Elasticutor's per-operator executors; Röger & Mayer's
//! survey), with no state transfer anywhere.
//!
//! Mechanics of the hand-off gate, built by [`PipelineBuilder::stage`]:
//!
//! * sources = upstream stage's `max` worker slots **plus one reserved
//!   control slot** (the last source id), readers = downstream stage's
//!   `max` worker slots;
//! * data flows ESG-native and *batch-native* (§Perf): upstream workers
//!   stage their emissions and hand whole ts-sorted runs over with one
//!   batched add per [`VsnOptions::worker_batch`] tuples, downstream
//!   workers take runs via `get_batch`, their handle clocks carry the
//!   watermark (Lemma 2), and they forward explicit heartbeat entries so
//!   downstream windows expire when rates drop to zero;
//! * reconfigurations of the downstream stage enter through the reserved
//!   control slot ([`ControlInjector`]): the slot is activated with the
//!   gate's current readiness bound as its Lemma-3 clock floor, the
//!   control tuple (stamped γ = that bound) is added, and the slot is
//!   removed again — the paper's addSources/removeSources dance, so an
//!   idle control slot never gates readiness.
//!
//! Stage chaining is *typed*: `PipelineBuilder<In, Cur>` only accepts a
//! next stage whose operator consumes `Cur`. Engines are constructed
//! lazily (a stage's ESG_out geometry depends on the NEXT stage's
//! parallelism), which is why the builder carries a deferred finisher
//! closure instead of a live engine.

use crate::engine::ingress::ControlPlane;
use crate::engine::vsn::{EngineClock, StageIo, VsnEngine, VsnOptions};
use crate::engine::StretchIngress;
use crate::metrics::OperatorMetrics;
use crate::operator::{OperatorDef, OperatorLogic};
use crate::scalegate::{AddError, Esg, EsgConfig, ReaderHandle, SourceHandle};
use crate::time::{EventTime, TIME_MAX, TIME_MIN};
use crate::tuple::{Epoch, InstanceId, Mapper, Payload, ReconfigSpec, Tuple};
use crate::util::Backoff;
use std::sync::Arc;
use std::time::Instant;

/// Injects control tuples for a mid-pipeline stage through the reserved
/// control slot of its (shared) ESG_in. See the module docs for the
/// activate → add → remove protocol.
pub struct ControlInjector<P: Payload + Default> {
    src: SourceHandle<Tuple<P>>,
    control: Arc<ControlPlane>,
    last_ts: EventTime,
    /// Target tag stamped into `Tuple::input`: a shared fan-out gate
    /// broadcasts control tuples to every consumer stage's readers, and
    /// only workers whose stage tag matches adopt the spec.
    tag: u8,
}

impl<P: Payload + Default> ControlInjector<P> {
    pub fn new(src: SourceHandle<Tuple<P>>, control: Arc<ControlPlane>) -> Self {
        ControlInjector { src, control, last_ts: TIME_MIN, tag: 0 }
    }

    /// Address a specific consumer stage of a shared gate (DAG fan-out).
    pub fn with_tag(mut self, tag: u8) -> Self {
        self.tag = tag;
        self
    }

    /// Issue (e*, 𝕆*, f_μ*) to the stage. Returns the new epoch id.
    pub fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch {
        let epoch = self.control.allocate_epoch();
        let spec = ReconfigSpec { epoch, instances: Arc::new(instances), mapper };
        self.control.note_issued(epoch, Instant::now());
        // γ: the gate's current readiness bound — the switch triggers on
        // the first watermark advance past "now". Monotone per slot (the
        // slot's stream must stay ts-sorted across injections).
        let bound = self.src.gate().clock_bound();
        let ts = if bound >= TIME_MAX { self.last_ts.max(0) } else { bound.max(self.last_ts) };
        self.last_ts = ts;
        let gate = self.src.gate();
        let activated = gate.add_sources(&[self.src.id()], ts);
        debug_assert!(activated, "reserved control slot unexpectedly active");
        // force_add: exempt from the data flow-control bound — the driver
        // thread must not deadlock behind backpressure it is responsible
        // for draining further downstream. Bounded by the slot queue.
        let mut t = Tuple::control(ts, spec);
        t.input = self.tag;
        let mut backoff = Backoff::active();
        loop {
            match self.src.force_add(t) {
                Ok(()) => break,
                Err(AddError::Inactive(_)) => unreachable!("control slot deactivated mid-add"),
                Err(AddError::Full(back)) => {
                    t = back;
                    backoff.snooze();
                }
            }
        }
        gate.remove_sources(&[self.src.id()]);
        epoch
    }
}

/// Type-erased per-stage handle: control, metrics and lifecycle of one
/// VSN stage, independent of its operator's payload types.
pub trait StageHandle: Send {
    /// Operator name (metrics, logs).
    fn name(&self) -> &'static str;
    /// Issue a reconfiguration to THIS stage (first stage: via its
    /// control plane + ingress wrappers; later stages: via the reserved
    /// control slot). Returns the new epoch id.
    fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch;
    /// The stage's shared operator metrics.
    fn metrics(&self) -> Arc<OperatorMetrics>;
    /// Currently active instance ids (𝕆 of the installed epoch).
    fn active_instances(&self) -> Vec<InstanceId>;
    /// Maximum parallelism n (pool included).
    fn max_parallelism(&self) -> usize;
    /// Pending backlog on the stage's ESG_in (flow-control signal).
    fn in_backlog(&self) -> u64;
    /// Completed reconfigurations of this stage: (epoch, wall ms).
    fn completion_times(&self) -> Vec<(Epoch, f64)>;
    /// Stop and join the stage's instance threads.
    fn shutdown(&mut self);
}

/// A [`StageHandle`] over a live [`VsnEngine`]. Shared with the DAG
/// builder ([`crate::engine::dag`]), which wires the same engine over
/// offset slot ranges of shared gates.
pub(crate) struct VsnStage<L: OperatorLogic>
where
    L::In: Default,
    L::Out: Default,
{
    name: &'static str,
    engine: VsnEngine<L>,
    /// `None` for the first stage (control rides the ingress wrappers).
    injector: Option<ControlInjector<L::In>>,
    max: usize,
}

impl<L: OperatorLogic> VsnStage<L>
where
    L::In: Default,
    L::Out: Default,
{
    pub(crate) fn new(
        name: &'static str,
        engine: VsnEngine<L>,
        injector: Option<ControlInjector<L::In>>,
        max: usize,
    ) -> Self {
        VsnStage { name, engine, injector, max }
    }
}

impl<L: OperatorLogic> StageHandle for VsnStage<L>
where
    L::In: Default,
    L::Out: Default,
{
    fn name(&self) -> &'static str {
        self.name
    }

    fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch {
        match &mut self.injector {
            Some(inj) => inj.reconfigure(instances, mapper),
            None => self.engine.control.reconfigure(instances, mapper),
        }
    }

    fn metrics(&self) -> Arc<OperatorMetrics> {
        self.engine.metrics.clone()
    }

    fn active_instances(&self) -> Vec<InstanceId> {
        self.engine.epoch_config().instances.as_ref().clone()
    }

    fn max_parallelism(&self) -> usize {
        self.max
    }

    fn in_backlog(&self) -> u64 {
        self.engine.in_backlog()
    }

    fn completion_times(&self) -> Vec<(Epoch, f64)> {
        self.engine.control.completion_times()
    }

    fn shutdown(&mut self) {
        self.engine.shutdown();
    }
}

/// A running multi-stage topology — a linear chain from
/// [`PipelineBuilder`] or a general DAG from
/// [`crate::engine::dag::DagBuilder`]: external ingress wrappers into the
/// source stage(s), egress readers off the sink stage(s), and a
/// type-erased handle per stage (declaration order, upstream first).
pub struct Pipeline<In: Payload + Default, Out: Payload + Default> {
    /// Shared wall-clock origin of every stage (end-to-end latency).
    pub clock: EngineClock,
    /// addSTRETCH wrappers over the source stages' ESG_in sources.
    pub ingress: Vec<StretchIngress<In>>,
    /// Reader ends of the sink stages' output gates.
    pub egress: Vec<ReaderHandle<Tuple<Out>>>,
    /// The final output gate of every sink stage (diagnostics: backlog,
    /// published count). One entry for linear chains.
    pub out_gates: Vec<Esg<Tuple<Out>>>,
    /// One handle per stage, upstream first.
    pub stages: Vec<Box<dyn StageHandle>>,
}

impl<In: Payload + Default, Out: Payload + Default> Pipeline<In, Out> {
    /// Number of stages.
    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    /// Reconfigure stage `k` to the given instance set (hash-mod mapper
    /// over it). Returns the stage's new epoch id.
    pub fn reconfigure_stage(&mut self, k: usize, instances: Vec<InstanceId>) -> Epoch {
        let mapper = Mapper::over(instances.clone());
        self.stages[k].reconfigure(instances, mapper)
    }

    /// Stop every stage, upstream first (so downstream gates drain).
    pub fn shutdown(&mut self) {
        for s in self.stages.iter_mut() {
            s.shutdown();
        }
    }
}

/// The deferred finisher of the most recently declared stage: given its
/// ESG_out (gate + this stage's worker source ends), spawn the engine and
/// return the type-erased handle (plus ingress wrappers — non-empty only
/// for stage 0).
type Finish<In, Out> = Box<
    dyn FnOnce(
        Esg<Tuple<Out>>,
        Vec<SourceHandle<Tuple<Out>>>,
    ) -> (Box<dyn StageHandle>, Vec<StretchIngress<In>>),
>;

/// Typed builder: `PipelineBuilder::new(def₀, opts₀).stage(def₁, opts₁)
/// .…​.build()`. `In` is the pipeline input payload, `Cur` the output
/// payload of the last declared stage (the only thing the next stage may
/// consume).
pub struct PipelineBuilder<In: Payload + Default, Cur: Payload + Default> {
    clock: EngineClock,
    stages: Vec<Box<dyn StageHandle>>,
    ingress: Vec<StretchIngress<In>>,
    finish: Finish<In, Cur>,
    /// Options of the pending (last declared, not yet spawned) stage —
    /// they size its ESG_out.
    pending_opts: VsnOptions,
}

impl<In: Payload + Default, Cur: Payload + Default> PipelineBuilder<In, Cur> {
    /// Start a pipeline with its source stage. `opts.upstreams` external
    /// sources feed the stage's ESG_in through [`StretchIngress`]
    /// wrappers returned by [`PipelineBuilder::build`].
    pub fn new<L>(def: OperatorDef<L>, opts: VsnOptions) -> PipelineBuilder<In, Cur>
    where
        L: OperatorLogic<In = In, Out = Cur>,
    {
        let clock = EngineClock::new();
        let (esg_in, in_sources, in_readers) =
            Esg::new(opts.in_gate_config(), opts.upstreams, opts.initial);
        let name = def.name;
        let clock2 = clock.clone();
        let opts2 = opts.clone();
        let finish: Finish<In, Cur> = Box::new(move |esg_out, out_sources| {
            let io = StageIo {
                esg_in,
                in_sources,
                in_readers,
                esg_out,
                out_sources,
                reader_base: 0,
                source_base: 0,
                ctrl_tag: 0,
            };
            let max = opts2.max;
            let (engine, ingress) = VsnEngine::setup_with_gates(def, opts2, io, clock2);
            (Box::new(VsnStage::new(name, engine, None, max)) as Box<dyn StageHandle>, ingress)
        });
        PipelineBuilder { clock, stages: Vec::new(), ingress: Vec::new(), finish, pending_opts: opts }
    }

    /// Chain the next stage: builds the shared hand-off gate (upstream's
    /// ESG_out ≡ this stage's ESG_in), finishes the upstream stage over
    /// it, and defers this stage until ITS output geometry is known.
    /// `opts.upstreams` is ignored for chained stages — their input
    /// sources are the upstream workers plus the reserved control slot.
    pub fn stage<L>(self, def: OperatorDef<L>, opts: VsnOptions) -> PipelineBuilder<In, L::Out>
    where
        L: OperatorLogic<In = Cur>,
        L::Out: Default,
    {
        let up = &self.pending_opts;
        // +1 writer slot: the downstream stage's reserved control slot.
        let cfg = EsgConfig::for_gate(up.max + 1, opts.max, opts.gate_capacity);
        let (gate, mut sources, readers) = Esg::new(cfg, up.initial, opts.initial);
        let ctrl_src = sources.pop().expect("control slot");
        debug_assert_eq!(sources.len(), up.max);
        let (handle, ingress0) = (self.finish)(gate.clone(), sources);
        let mut stages = self.stages;
        stages.push(handle);
        let mut ingress = self.ingress;
        ingress.extend(ingress0);

        let name = def.name;
        let clock2 = self.clock.clone();
        let opts2 = opts.clone();
        let finish: Finish<In, L::Out> = Box::new(move |esg_out, out_sources| {
            let io = StageIo {
                esg_in: gate,
                in_sources: Vec::new(),
                in_readers: readers,
                esg_out,
                out_sources,
                reader_base: 0,
                source_base: 0,
                ctrl_tag: 0,
            };
            let max = opts2.max;
            let (engine, _no_ingress) = VsnEngine::setup_with_gates(def, opts2, io, clock2);
            let injector = ControlInjector::new(ctrl_src, engine.control.clone());
            (
                Box::new(VsnStage::new(name, engine, Some(injector), max))
                    as Box<dyn StageHandle>,
                Vec::new(),
            )
        });
        PipelineBuilder {
            clock: self.clock,
            stages,
            ingress,
            finish,
            pending_opts: opts,
        }
    }

    /// Terminate the pipeline: build the last stage's ESG_out with
    /// `pending_opts.egress_readers` reader ends and spawn it.
    pub fn build(self) -> Pipeline<In, Cur> {
        let po = &self.pending_opts;
        let (gate, sources, readers) = Esg::new(po.out_gate_config(), po.initial, po.egress_readers);
        let (handle, ingress0) = (self.finish)(gate.clone(), sources);
        let mut stages = self.stages;
        stages.push(handle);
        let mut ingress = self.ingress;
        ingress.extend(ingress0);
        Pipeline { clock: self.clock, ingress, egress: readers, out_gates: vec![gate], stages }
    }
}
