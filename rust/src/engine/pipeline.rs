//! Multi-stage VSN pipelines (§7: "STRETCH can be used to instantiate
//! many (connected) operators within a query ... the ESG_out of such an
//! upstream peer" acts as the downstream's ESG_in).
//!
//! A pipeline composes `source → stage₁ → … → stageₖ → sink` where stage
//! N's ESG_out **is** stage N+1's ESG_in: one shared gate, zero-copy
//! hand-off, no re-ingestion. Each stage keeps its own instance pool,
//! epoch protocol and [`ControlPlane`], so stages reconfigure
//! *independently* — elasticity is a per-operator property of the
//! topology (Elasticutor's per-operator executors; Röger & Mayer's
//! survey), with no state transfer anywhere.
//!
//! Since PR 4 a linear chain is *literally* a degenerate DAG:
//! [`PipelineBuilder`] is a thin typed façade over
//! [`crate::engine::dag::DagBuilder`] — every `stage()` call declares a
//! DAG node consuming the previous one, and `build()` delegates gate
//! construction (slot geometry, reserved per-edge control slots,
//! reader/source groups, elasticity wiring) to the one shared DAG
//! construction path. See the [`crate::engine::dag`] module docs for the
//! hand-off gate mechanics; data still flows ESG-native and batch-native
//! (§Perf), watermarks via handle clocks plus forwarded heartbeat
//! entries, and downstream reconfigurations via [`ControlInjector`]'s
//! activate → add → remove protocol over the reserved control slot.
//!
//! Stage chaining is *typed*: `PipelineBuilder<In, Cur>` only accepts a
//! next stage whose operator consumes `Cur`.
//!
//! This module keeps the pieces every topology shape shares: the
//! type-erased [`StageHandle`]/[`VsnStage`], the running [`Pipeline`],
//! and [`ControlInjector`].

use crate::engine::dag::{DagBuilder, NodeHandle};
use crate::engine::ingress::ControlPlane;
use crate::engine::vsn::{EngineClock, VsnEngine, VsnOptions};
use crate::engine::StretchIngress;
use crate::metrics::OperatorMetrics;
use crate::operator::{OperatorDef, OperatorLogic};
use crate::scalegate::{AddError, Esg, ReaderHandle, SourceHandle};
use crate::time::{EventTime, TIME_MAX, TIME_MIN};
use crate::tuple::{Epoch, InstanceId, Mapper, Payload, ReconfigSpec, Tuple};
use crate::util::Backoff;
use std::sync::Arc;
use std::time::Instant;

/// Injects control tuples for a mid-pipeline stage through the reserved
/// control slot of its (shared) ESG_in. See the module docs for the
/// activate → add → remove protocol.
pub struct ControlInjector<P: Payload + Default> {
    src: SourceHandle<Tuple<P>>,
    control: Arc<ControlPlane>,
    last_ts: EventTime,
    /// Target tag stamped into `Tuple::input`: a shared fan-out gate
    /// broadcasts control tuples to every consumer stage's readers, and
    /// only workers whose stage tag matches adopt the spec.
    tag: u8,
}

impl<P: Payload + Default> ControlInjector<P> {
    pub fn new(src: SourceHandle<Tuple<P>>, control: Arc<ControlPlane>) -> Self {
        ControlInjector { src, control, last_ts: TIME_MIN, tag: 0 }
    }

    /// Address a specific consumer stage of a shared gate (DAG fan-out).
    pub fn with_tag(mut self, tag: u8) -> Self {
        self.tag = tag;
        self
    }

    /// Issue (e*, 𝕆*, f_μ*) to the stage. Returns the new epoch id.
    pub fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch {
        let epoch = self.control.allocate_epoch();
        let spec = ReconfigSpec { epoch, instances: Arc::new(instances), mapper };
        self.control.note_issued(epoch, Instant::now());
        // γ: the gate's current readiness bound — the switch triggers on
        // the first watermark advance past "now". Monotone per slot (the
        // slot's stream must stay ts-sorted across injections).
        let bound = self.src.gate().clock_bound();
        let ts = if bound >= TIME_MAX { self.last_ts.max(0) } else { bound.max(self.last_ts) };
        self.last_ts = ts;
        let gate = self.src.gate();
        let activated = gate.add_sources(&[self.src.id()], ts);
        debug_assert!(activated, "reserved control slot unexpectedly active");
        // force_add: exempt from the data flow-control bound — the driver
        // thread must not deadlock behind backpressure it is responsible
        // for draining further downstream. Bounded by the slot queue.
        let mut t = Tuple::control(ts, spec);
        t.input = self.tag;
        let mut backoff = Backoff::active();
        loop {
            match self.src.force_add(t) {
                Ok(()) => break,
                Err(AddError::Inactive(_)) => unreachable!("control slot deactivated mid-add"),
                Err(AddError::Full(back)) => {
                    t = back;
                    backoff.snooze();
                }
            }
        }
        gate.remove_sources(&[self.src.id()]);
        epoch
    }
}

/// Type-erased per-stage handle: control, metrics and lifecycle of one
/// VSN stage, independent of its operator's payload types. This is the
/// per-stage half of the live-job control surface — the job runtime
/// ([`crate::harness::Job`]) owns a `Box<dyn StageHandle>` per stage and
/// serves `scale`/`sample`/`set_worker_batch` calls through it.
pub trait StageHandle: Send {
    /// Operator name (metrics, logs).
    fn name(&self) -> &'static str;
    /// Issue a reconfiguration to THIS stage (first stage: via its
    /// control plane + ingress wrappers; later stages: via the reserved
    /// control slot). Returns the new epoch id.
    fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch;
    /// Scale this stage to `n` active instances — keep existing ids,
    /// grow from the lowest pool ids, shrink from the highest (the pool
    /// semantics of §7) — and return the new epoch id.
    fn scale_to(&mut self, n: usize) -> Epoch {
        let set =
            crate::elastic::resize_instance_set(&self.active_instances(), self.max_parallelism(), n);
        let mapper = Mapper::over(set.clone());
        self.reconfigure(set, mapper)
    }
    /// The stage's shared operator metrics.
    fn metrics(&self) -> Arc<OperatorMetrics>;
    /// Currently active instance ids (𝕆 of the installed epoch).
    fn active_instances(&self) -> Vec<InstanceId>;
    /// Maximum parallelism n (pool included).
    fn max_parallelism(&self) -> usize;
    /// Pending backlog on the stage's ESG_in (flow-control signal).
    fn in_backlog(&self) -> u64;
    /// Current effective worker batch (tuples per gate synchronization).
    fn worker_batch(&self) -> usize;
    /// Retune the worker batch at runtime — adaptive batch sizing: the
    /// harness derives it from observed `in_backlog`, clamped to
    /// [`crate::config::BatchTuning`] min/max, each controller tick.
    fn set_worker_batch(&self, n: usize);
    /// Completed reconfigurations of this stage: (epoch, wall ms).
    fn completion_times(&self) -> Vec<(Epoch, f64)>;
    /// The stage's per-worker health slab (supervision + fault
    /// injection). `None` for engines without a supervision surface.
    fn worker_health(&self) -> Option<Arc<crate::engine::vsn::WorkerHealth>> {
        None
    }
    /// Stop and join the stage's instance threads.
    fn shutdown(&mut self);
}

/// A [`StageHandle`] over a live [`VsnEngine`]. Shared with the DAG
/// builder ([`crate::engine::dag`]), which wires the same engine over
/// offset slot ranges of shared gates.
pub(crate) struct VsnStage<L: OperatorLogic>
where
    L::In: Default,
    L::Out: Default,
{
    name: &'static str,
    engine: VsnEngine<L>,
    /// `None` for the first stage (control rides the ingress wrappers).
    injector: Option<ControlInjector<L::In>>,
    max: usize,
}

impl<L: OperatorLogic> VsnStage<L>
where
    L::In: Default,
    L::Out: Default,
{
    pub(crate) fn new(
        name: &'static str,
        engine: VsnEngine<L>,
        injector: Option<ControlInjector<L::In>>,
        max: usize,
    ) -> Self {
        VsnStage { name, engine, injector, max }
    }
}

impl<L: OperatorLogic> StageHandle for VsnStage<L>
where
    L::In: Default,
    L::Out: Default,
{
    fn name(&self) -> &'static str {
        self.name
    }

    fn reconfigure(&mut self, instances: Vec<InstanceId>, mapper: Mapper) -> Epoch {
        match &mut self.injector {
            Some(inj) => inj.reconfigure(instances, mapper),
            None => self.engine.control.reconfigure(instances, mapper),
        }
    }

    fn metrics(&self) -> Arc<OperatorMetrics> {
        self.engine.metrics.clone()
    }

    fn active_instances(&self) -> Vec<InstanceId> {
        self.engine.epoch_config().instances.as_ref().clone()
    }

    fn max_parallelism(&self) -> usize {
        self.max
    }

    fn in_backlog(&self) -> u64 {
        self.engine.in_backlog()
    }

    fn worker_batch(&self) -> usize {
        self.engine.worker_batch()
    }

    fn set_worker_batch(&self, n: usize) {
        self.engine.set_worker_batch(n);
    }

    fn completion_times(&self) -> Vec<(Epoch, f64)> {
        self.engine.control.completion_times()
    }

    fn worker_health(&self) -> Option<Arc<crate::engine::vsn::WorkerHealth>> {
        Some(self.engine.health())
    }

    fn shutdown(&mut self) {
        self.engine.shutdown();
    }
}

/// A running multi-stage topology — a linear chain from
/// [`PipelineBuilder`] or a general DAG from
/// [`crate::engine::dag::DagBuilder`]: external ingress wrappers into the
/// source stage(s), egress readers off the sink stage(s), and a
/// type-erased handle per stage (declaration order, upstream first).
pub struct Pipeline<In: Payload + Default, Out: Payload + Default> {
    /// Shared wall-clock origin of every stage (end-to-end latency).
    pub clock: EngineClock,
    /// addSTRETCH wrappers over the source stages' ESG_in sources.
    pub ingress: Vec<StretchIngress<In>>,
    /// Reader ends of the sink stages' output gates.
    pub egress: Vec<ReaderHandle<Tuple<Out>>>,
    /// The final output gate of every sink stage (diagnostics: backlog,
    /// published count). One entry for linear chains.
    pub out_gates: Vec<Esg<Tuple<Out>>>,
    /// One handle per stage, upstream first.
    pub stages: Vec<Box<dyn StageHandle>>,
}

impl<In: Payload + Default, Out: Payload + Default> Pipeline<In, Out> {
    /// Number of stages.
    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    /// Reconfigure stage `k` to the given instance set (hash-mod mapper
    /// over it). Returns the stage's new epoch id.
    pub fn reconfigure_stage(&mut self, k: usize, instances: Vec<InstanceId>) -> Epoch {
        let mapper = Mapper::over(instances.clone());
        self.stages[k].reconfigure(instances, mapper)
    }

    /// Stop every stage, upstream first (so downstream gates drain).
    pub fn shutdown(&mut self) {
        for s in self.stages.iter_mut() {
            s.shutdown();
        }
    }
}

/// Typed builder: `PipelineBuilder::new(def₀, opts₀).stage(def₁, opts₁)
/// .…​.build()`. `In` is the pipeline input payload, `Cur` the output
/// payload of the last declared stage (the only thing the next stage may
/// consume).
///
/// A linear chain is just a degenerate DAG, so this builder constructs
/// NOTHING itself: it is a thin typed façade over
/// [`crate::engine::dag::DagBuilder`] — `new` declares the source node,
/// every `stage` call declares a node consuming the previous one, and
/// `build` hands the whole chain to [`DagBuilder::build`]. Gates,
/// reader/source slot groups, reserved control slots and elasticity
/// wiring therefore come from ONE construction path shared with every
/// other topology shape (see the [`crate::engine::dag`] module docs for
/// the mechanics).
pub struct PipelineBuilder<In: Payload + Default, Cur: Payload + Default> {
    dag: DagBuilder<In>,
    last: NodeHandle<Cur>,
}

impl<In: Payload + Default, Cur: Payload + Default> PipelineBuilder<In, Cur> {
    /// Start a pipeline with its source stage. `opts.upstreams` external
    /// sources feed the stage's ESG_in through [`StretchIngress`]
    /// wrappers returned by [`PipelineBuilder::build`].
    pub fn new<L>(def: OperatorDef<L>, opts: VsnOptions) -> PipelineBuilder<In, Cur>
    where
        L: OperatorLogic<In = In, Out = Cur>,
    {
        let mut dag = DagBuilder::new();
        let last = dag.source(def, opts);
        PipelineBuilder { dag, last }
    }

    /// Chain the next stage through a shared hand-off gate (upstream's
    /// ESG_out ≡ this stage's ESG_in, plus a reserved control slot).
    /// `opts.upstreams` is ignored for chained stages — their input
    /// sources are the upstream workers plus the reserved control slot.
    pub fn stage<L>(mut self, def: OperatorDef<L>, opts: VsnOptions) -> PipelineBuilder<In, L::Out>
    where
        L: OperatorLogic<In = Cur>,
        L::Out: Default,
    {
        let last = self.dag.node(def, opts, &[self.last]);
        PipelineBuilder { dag: self.dag, last }
    }

    /// Terminate the pipeline: the last declared stage becomes the sole
    /// sink, its ESG_out gets `opts.egress_readers` reader ends.
    pub fn build(self) -> Pipeline<In, Cur> {
        self.dag
            .build(&[self.last])
            .expect("a linear chain is always a valid DAG")
    }
}
