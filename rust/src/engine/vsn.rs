//! The VSN (STRETCH) engine: `setup(O+, m, n)` (§7, Fig. 5).
//!
//! Creates n `o+` instances sharing the state σ, connects m of them to
//! `ESG_in`/`ESG_out` and parks the remaining n−m in the pool. Each
//! instance runs `processVSN` (Alg. 4) on its own thread: poll `ESG_in`,
//! handle control tuples (Alg. 6), trigger epoch switches at the barrier,
//! perform gate membership changes (exactly one instance succeeds — the
//! ESG arbitration), then run the shared [`OperatorCore`].
//!
//! Construction is split in two (the pipeline refactor): gate
//! construction ([`VsnOptions::in_gate_config`]/[`VsnOptions::out_gate_config`]
//! + [`Esg::new`]) and worker spawning over externally supplied gate ends
//! ([`VsnEngine::setup_with_gates`]). Two engines can therefore *share* a
//! gate — stage N's ESG_out is stage N+1's ESG_in, the zero-copy hand-off
//! behind [`crate::engine::pipeline`]. [`VsnEngine::setup`] composes the
//! two halves for the classic single-operator shape.
//!
//! ## Supervision & fault containment
//! Each worker's batch loop runs under `catch_unwind`: an operator panic
//! marks the slot [`WorkerState::Dead`] in the per-stage [`WorkerHealth`]
//! slab and flips the worker into *zombie* mode — it keeps reading (so
//! epoch barriers still form and its backlog share stays GC-accounted)
//! but processes nothing, never beats, and never advances its out clock.
//! The frozen clock holds the downstream merge at the death watermark; at
//! the healing epoch switch the zombie replays its pinned unprocessed
//! share `[first_unprocessed, S)` through the ordinary `handle_input`
//! path (recovery IS reconfiguration — no state transfer), a second
//! barrier orders slot removal after the replay, and the thread exits
//! once its reader is decommissioned. Fault-model boundaries, by design:
//! injected kills panic at an exact batch boundary so replay is exact;
//! a *real* mid-tuple panic drops the in-flight tuple's partial staged
//! emissions and replays it in full, which is exactly-once for emissions
//! but at-least-once for that one tuple's shared-state side effects; a
//! panic that poisons a shard lock cascades to the other instances
//! touching that shard (they die and heal the same way); a second panic
//! during replay abandons the dead share. During a recovery window the
//! out-gate bound freezes at the dead worker's clock, so survivors can
//! only run ahead by their per-source SPSC queue capacity — supervision
//! must heal promptly (the shipped [`crate::harness::policy`] supervisor
//! reacts on its first tick).
//!
//! ## Memory-ordering protocol
//! The engine's own lock-free edges (the gates carry their own):
//! * **health slab** — `state` transitions publish with Release
//!   (`mark_dead`, the beat/stall CASes) and are read with Acquire
//!   (`state()`), so `do_reconfig`'s same-answer-everywhere dead check
//!   is sound; `progress`/`last_advance_us` are Relaxed monitoring
//!   counters (the detector acts on values, not on inter-variable
//!   ordering).
//! * **fault injection** — `inject`'s Release store pairs with
//!   `take_fault`'s Acquire swap: the worker that picks a fault up sees
//!   everything the injector wrote before arming it.
//! * **shutdown** — `running` Release store / Acquire loads; the flag
//!   is the only channel, workers re-check it on every loop.
//! * **batch knob** — Relaxed both sides: a tuning value acted on by
//!   itself, synchronizing nothing.
//!
//! ## Run-buffer lifecycle (§Perf memory discipline)
//! Each worker owns exactly two run buffers for its whole life — the
//! input batch scratch (filled by `get_batch`, drained by `pop`) and
//! the staged-emission buffer `out_buf` (filled by the operator,
//! drained in place by `try_add_batch`) — both drawn from the owning
//! gate's [`crate::util::BufferPool`] at spawn and handed back at
//! thread exit (shutdown, or a healed zombie's decommission), so
//! reconfiguration recycles buffers instead of allocating. In between,
//! the buffers circulate privately: steady state performs zero
//! allocator calls per tuple (`bench_micro` asserts this). Burst
//! capacity decays at batch boundaries via [`pool::shrink_excess`].

use crate::engine::barrier::EpochBarrier;
use crate::engine::epoch::{EpochConfig, EpochState, PendingReconfig};
use crate::engine::ingress::{ControlPlane, StretchIngress};
use crate::metrics::{Histogram, OperatorMetrics};
use crate::operator::state::SharedState;
use crate::operator::{Ctx, OperatorCore, OperatorDef, OperatorLogic};
use crate::scalegate::{Esg, EsgConfig, ReaderHandle, SourceHandle};
use crate::time::EventTime;
use crate::tuple::{InstanceId, Kind, Mapper, Tuple};
use crate::util::pool;
use crate::util::{Backoff, CachePadded};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default tuples a worker takes from ESG_in per gate synchronization
/// (see [`ReaderHandle::get_batch`]) and emits downstream per
/// [`SourceHandle::add_batch`]; also the egress drain granularity.
/// Tunable per engine via [`VsnOptions::worker_batch`] /
/// [`crate::config::BatchTuning`].
pub const WORKER_BATCH: usize = 128;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct VsnOptions {
    /// Initial parallelism degree m.
    pub initial: usize,
    /// Maximum parallelism degree n (pool size = n − m).
    pub max: usize,
    /// Number of upstream instances feeding ESG_in.
    pub upstreams: usize,
    /// Readers on ESG_out (egress or downstream instances).
    pub egress_readers: usize,
    /// Flow-control capacity of each gate (§8's bounded ESG).
    pub gate_capacity: usize,
    /// σ shard count.
    pub shards: usize,
    /// Tuples moved per worker gate synchronization, in and out
    /// ([`ReaderHandle::get_batch`] / [`SourceHandle::add_batch`]).
    pub worker_batch: usize,
    /// Kernel core ids the instance threads pin themselves to (instance
    /// id indexes the list; empty = no pinning). Cover ALL `max` slots,
    /// not just `initial`: pooled instances spawn during the same build
    /// and inherit the spawning thread's affinity mask otherwise. Filled
    /// by a `runtime::placement::PlacementPlan`.
    pub worker_cores: Vec<usize>,
}

impl Default for VsnOptions {
    fn default() -> Self {
        VsnOptions {
            initial: 1,
            max: 4,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: 1 << 15,
            shards: crate::operator::state::DEFAULT_SHARDS,
            worker_batch: WORKER_BATCH,
            worker_cores: Vec::new(),
        }
    }
}

impl VsnOptions {
    /// Apply the `[batch]` section of an experiment config.
    pub fn with_batch(mut self, tuning: &crate::config::BatchTuning) -> Self {
        self.worker_batch = tuning.worker.max(1);
        self
    }
    /// ESG_in geometry: `upstreams` writers, up to `max` worker readers.
    pub fn in_gate_config(&self) -> EsgConfig {
        EsgConfig::for_gate(self.upstreams, self.max, self.gate_capacity)
    }

    /// ESG_out geometry: up to `max` worker writers, `egress_readers`
    /// readers.
    pub fn out_gate_config(&self) -> EsgConfig {
        EsgConfig::for_gate(self.max, self.egress_readers, self.gate_capacity)
    }
}

/// Wall-clock origin shared by ingress stampers and egress latency
/// accounting. Pipelines share ONE clock across all stages so end-to-end
/// latency stamps stay comparable.
#[derive(Clone)]
pub struct EngineClock(Arc<Instant>);

impl EngineClock {
    pub fn new() -> Self {
        EngineClock(Arc::new(Instant::now()))
    }
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

impl Default for EngineClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Lifecycle of one worker slot as the supervision layer sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Processing (or idle with nothing to do).
    Live,
    /// Progress stopped while backlog is nonzero (detector-classified) or
    /// an injected stall is in effect. Recovers by itself: the next
    /// processed batch flips the slot back to [`WorkerState::Live`].
    Stalled,
    /// The worker panicked (or an injected kill fired). Terminal for the
    /// slot — dead instances leave the epoch via reconfiguration and
    /// their threads exit once decommissioned.
    Dead,
}

/// A scripted fault armed into a worker's health slot; the worker applies
/// it at its next batch boundary ([`WorkerHealth::inject`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectedFault {
    /// Panic before popping any tuple of the next batch — containment
    /// catches it at an exact batch boundary, so crash replay is exact.
    Kill,
    /// Stop reading, beating and advancing clocks for this many wall ms,
    /// then resume and catch up (position-deterministic, so exactly-once
    /// needs no repair).
    Stall(u64),
    /// Sleep this many microseconds before each processed batch.
    Slow(u64),
}

const FAULT_NONE: u64 = 0;
const FAULT_KILL: u64 = 1;
const FAULT_STALL: u64 = 2;
const FAULT_SLOW: u64 = 3;

impl InjectedFault {
    fn encode(self) -> u64 {
        match self {
            InjectedFault::Kill => FAULT_KILL,
            InjectedFault::Stall(ms) => FAULT_STALL | (ms << 8),
            InjectedFault::Slow(us) => FAULT_SLOW | (us << 8),
        }
    }

    fn decode(v: u64) -> Option<InjectedFault> {
        match v & 0xff {
            FAULT_NONE => None,
            FAULT_KILL => Some(InjectedFault::Kill),
            FAULT_STALL => Some(InjectedFault::Stall(v >> 8)),
            FAULT_SLOW => Some(InjectedFault::Slow(v >> 8)),
            _ => None,
        }
    }
}

const STATE_LIVE: u8 = 0;
const STATE_STALLED: u8 = 1;
const STATE_DEAD: u8 = 2;

/// One worker slot's health cell. Cache-padded: the owning worker beats
/// into it once per batch while the runtime detector reads every slot
/// every tick — adjacent slots must not share a line.
struct HealthSlot {
    /// `WorkerState` encoding (`STATE_*`).
    state: AtomicU8,
    /// Monotone progress epoch: batches processed since launch.
    progress: AtomicU64,
    /// µs since the slab's origin at the last progress beat.
    last_advance_us: AtomicU64,
    /// Pending injected fault (encoded; 0 = none).
    fault: AtomicU64,
}

/// Point-in-time copy of one slot (detector / metrics consumption).
#[derive(Clone, Copy, Debug)]
pub struct WorkerHealthSnapshot {
    pub state: WorkerState,
    pub progress: u64,
    pub last_advance_us: u64,
}

/// Per-worker health slab shared between a stage's workers (writers), the
/// runtime detector (reader + stall classifier) and the fault injector.
/// One cache-padded slot per instance slot (`0..max`).
pub struct WorkerHealth {
    origin: Instant,
    slots: Vec<CachePadded<HealthSlot>>,
}

impl WorkerHealth {
    pub fn new(n: usize) -> Arc<Self> {
        let origin = Instant::now();
        Arc::new(WorkerHealth {
            origin,
            slots: (0..n)
                .map(|_| {
                    CachePadded::new(HealthSlot {
                        state: AtomicU8::new(STATE_LIVE),
                        progress: AtomicU64::new(0),
                        last_advance_us: AtomicU64::new(0),
                        fault: AtomicU64::new(FAULT_NONE),
                    })
                })
                .collect(),
        })
    }

    /// Number of slots (the stage's max parallelism).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// µs since the slab's origin — the time base of
    /// [`WorkerHealthSnapshot::last_advance_us`].
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Progress beat from worker `id`: bump the progress epoch, stamp the
    /// advance time, and clear a detector-applied stall mark. Never
    /// resurrects a dead slot.
    pub fn beat(&self, id: InstanceId) {
        let s = &self.slots[id];
        // ORDERING: Relaxed — monitoring counter; the detector compares
        // values across ticks and needs no happens-before from them.
        s.progress.fetch_add(1, Ordering::Relaxed);
        // ORDERING: Relaxed — monitoring timestamp, same argument.
        s.last_advance_us.store(self.now_us(), Ordering::Relaxed);
        // ORDERING: Release on success pairs with `state()`'s Acquire
        // (an observed-Live slot has the beat's progress stamp visible);
        // Relaxed on failure — the loaded value is discarded either way,
        // which is also why the success side needs no Acquire half
        // (weakened from AcqRel). Dead wins: the CAS only fires on
        // STALLED, never resurrecting a dead slot.
        let _ = s.state.compare_exchange(
            STATE_STALLED,
            STATE_LIVE,
            Ordering::Release,
            Ordering::Relaxed,
        );
    }

    /// Detector-side stall classification (progress epoch unchanged past
    /// the stall window while backlog is nonzero). Only a live slot can
    /// become stalled; the worker un-stalls itself at its next beat.
    pub fn mark_stalled(&self, id: InstanceId) {
        // ORDERING: Release on success pairs with `state()`'s Acquire;
        // Relaxed on failure — loaded value discarded on both paths, so
        // the success side needs no Acquire half (weakened from AcqRel).
        // Live-only: a dead slot never becomes merely stalled.
        let _ = self.slots[id].state.compare_exchange(
            STATE_LIVE,
            STATE_STALLED,
            Ordering::Release,
            Ordering::Relaxed,
        );
    }

    /// Worker-side death mark (caught panic). Terminal.
    ///
    /// ORDERING: Release pairs with `state()`'s Acquire — every write
    /// the dying worker made before the mark (pinned floor, replay seed)
    /// is visible to whoever observes it Dead.
    pub fn mark_dead(&self, id: InstanceId) {
        self.slots[id].state.store(STATE_DEAD, Ordering::Release);
    }

    /// ORDERING: Acquire pairs with the Release publishes in
    /// `mark_dead`/`mark_stalled`/`beat`.
    pub fn state(&self, id: InstanceId) -> WorkerState {
        match self.slots[id].state.load(Ordering::Acquire) {
            STATE_LIVE => WorkerState::Live,
            STATE_STALLED => WorkerState::Stalled,
            _ => WorkerState::Dead,
        }
    }

    /// ORDERING: Relaxed — monitoring counter, compared across ticks.
    pub fn progress(&self, id: InstanceId) -> u64 {
        self.slots[id].progress.load(Ordering::Relaxed)
    }

    /// ORDERING: Relaxed — monitoring stamp, compared across ticks.
    pub fn last_advance_us(&self, id: InstanceId) -> u64 {
        self.slots[id].last_advance_us.load(Ordering::Relaxed)
    }

    /// Arm a fault into slot `id`; the worker applies it at its next
    /// batch boundary. A second injection before pickup overwrites.
    ///
    /// ORDERING: Release pairs with `take_fault`'s Acquire — the worker
    /// that applies the fault sees everything the injector wrote first.
    pub fn inject(&self, id: InstanceId, fault: InjectedFault) {
        self.slots[id].fault.store(fault.encode(), Ordering::Release);
    }

    /// Worker-side pickup: take and clear the pending fault, if any.
    ///
    /// ORDERING: Acquire pairs with `inject`'s Release publish; the
    /// RMW's store half (clearing to `FAULT_NONE`) publishes nothing and
    /// nobody Acquire-loads it, so the Release half of the former AcqRel
    /// was unused — weakened to Acquire.
    pub fn take_fault(&self, id: InstanceId) -> Option<InjectedFault> {
        InjectedFault::decode(self.slots[id].fault.swap(FAULT_NONE, Ordering::Acquire))
    }

    /// Copy every slot (runtime detector / [`crate::harness`] metrics).
    pub fn snapshot(&self) -> Vec<WorkerHealthSnapshot> {
        (0..self.slots.len())
            .map(|i| WorkerHealthSnapshot {
                state: self.state(i),
                progress: self.progress(i),
                last_advance_us: self.last_advance_us(i),
            })
            .collect()
    }
}

/// The gate ends one engine needs: its input gate (with the worker-side
/// readers and any external-source handles) and its output gate (with the
/// worker-side sources). Output *readers* are not part of a stage — they
/// belong to whoever consumes the stage (egress driver or the downstream
/// stage's workers).
pub struct StageIo<L: OperatorLogic> {
    pub esg_in: Esg<Tuple<L::In>>,
    /// External writer endpoints of ESG_in; wrapped into [`StretchIngress`]
    /// (Alg. 5). Empty for mid-pipeline stages — their ESG_in is fed by
    /// the upstream stage's workers, not by external sources.
    pub in_sources: Vec<SourceHandle<Tuple<L::In>>>,
    /// Worker reader endpoints of ESG_in; exactly `opts.max` of them.
    pub in_readers: Vec<ReaderHandle<Tuple<L::In>>>,
    pub esg_out: Esg<Tuple<L::Out>>,
    /// Worker writer endpoints of ESG_out; exactly `opts.max` of them.
    pub out_sources: Vec<SourceHandle<Tuple<L::Out>>>,
    /// Gate slot index of `in_readers[0]`. On a shared fan-out gate each
    /// consumer stage owns a contiguous reader-slot range; instance j of
    /// this stage reads slot `reader_base + j`. 0 for private gates.
    pub reader_base: usize,
    /// Gate slot index of `out_sources[0]`. On a shared fan-in gate each
    /// upstream stage owns a contiguous source-slot range; instance j of
    /// this stage writes slot `source_base + j`. 0 for private gates.
    pub source_base: usize,
    /// This stage's control tag on its (possibly shared) ESG_in: control
    /// tuples are broadcast to every reader group of the gate, so workers
    /// only adopt specs whose `Tuple::input` matches their stage's tag.
    pub ctrl_tag: u8,
}

/// The running engine; dropping it shuts the instance threads down.
pub struct VsnEngine<L: OperatorLogic> {
    pub control: Arc<ControlPlane>,
    pub metrics: Arc<OperatorMetrics>,
    pub clock: EngineClock,
    pub esg_in: Esg<Tuple<L::In>>,
    pub esg_out: Esg<Tuple<L::Out>>,
    epoch: Arc<EpochState>,
    state: Arc<SharedState<L::State>>,
    running: Arc<AtomicBool>,
    /// Per-worker health slab (containment + detection + injection).
    health: Arc<WorkerHealth>,
    /// Live worker-batch tunable: workers re-read it every gate
    /// synchronization, so the harness can resize batches from observed
    /// backlog without a reconfiguration (adaptive batch sizing).
    batch_knob: Arc<AtomicUsize>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// This stage's reader-slot range on ESG_in (`backlog_range` group).
    in_reader_lo: usize,
    in_reader_hi: usize,
}

impl<L: OperatorLogic> VsnEngine<L>
where
    L::In: Default,
    L::Out: Default,
{
    /// `setup(O+, m, n)`: build gates, share σ, spawn n instance threads
    /// (m active). Returns the engine plus the upstream ingress wrappers
    /// and the ESG_out readers.
    pub fn setup(
        def: OperatorDef<L>,
        opts: VsnOptions,
    ) -> (Self, Vec<StretchIngress<L::In>>, Vec<ReaderHandle<Tuple<L::Out>>>) {
        let (esg_in, in_sources, in_readers) =
            Esg::new(opts.in_gate_config(), opts.upstreams, opts.initial);
        let (esg_out, out_sources, out_readers) =
            Esg::new(opts.out_gate_config(), opts.initial, opts.egress_readers);
        let io = StageIo {
            esg_in,
            in_sources,
            in_readers,
            esg_out,
            out_sources,
            reader_base: 0,
            source_base: 0,
            ctrl_tag: 0,
        };
        let (engine, ingress) = Self::setup_with_gates(def, opts, io, EngineClock::new());
        (engine, ingress, out_readers)
    }

    /// The worker-spawning half of `setup`: share σ, spawn the instance
    /// threads over externally constructed gate ends. This is how the
    /// pipeline layer chains stages through ONE shared gate — the caller
    /// builds `io.esg_in`/`io.esg_out` however it likes (fresh, or the
    /// upstream stage's ESG_out) as long as the worker endpoint counts
    /// equal `opts.max`.
    pub fn setup_with_gates(
        def: OperatorDef<L>,
        opts: VsnOptions,
        io: StageIo<L>,
        clock: EngineClock,
    ) -> (Self, Vec<StretchIngress<L::In>>) {
        assert!(opts.initial >= 1 && opts.initial <= opts.max);
        assert_eq!(io.in_readers.len(), opts.max, "need one ESG_in reader per instance slot");
        assert_eq!(io.out_sources.len(), opts.max, "need one ESG_out source per instance slot");
        let state: Arc<SharedState<L::State>> = SharedState::new(opts.shards);
        let metrics = OperatorMetrics::new(opts.max);
        let epoch = EpochState::new(EpochConfig {
            epoch: 0,
            instances: Arc::new((0..opts.initial).collect()),
            mapper: Mapper::hash_mod(opts.initial),
        });
        let control = ControlPlane::new(io.in_sources.len(), 0);
        let barrier = Arc::new(EpochBarrier::new());
        let running = Arc::new(AtomicBool::new(true));
        let health = WorkerHealth::new(opts.max);

        let batch = opts.worker_batch.max(1);
        let batch_knob = Arc::new(AtomicUsize::new(batch));
        let mut threads = Vec::with_capacity(opts.max);
        for (id, (reader, out)) in io.in_readers.into_iter().zip(io.out_sources).enumerate() {
            debug_assert_eq!(reader.id(), io.reader_base + id, "reader slot range mismatch");
            debug_assert_eq!(out.id(), io.source_base + id, "source slot range mismatch");
            let out_buf = out.pool().get(batch);
            let mut worker = Worker {
                core: OperatorCore::new(def.clone(), id, state.clone(), metrics.clone()),
                reader,
                out,
                out_buf,
                batch,
                batch_knob: batch_knob.clone(),
                epoch: epoch.clone(),
                barrier: barrier.clone(),
                control: control.clone(),
                running: running.clone(),
                health: health.clone(),
                cur: epoch.current(),
                pending: None,
                reader_base: io.reader_base,
                source_base: io.source_base,
                ctrl_tag: io.ctrl_tag,
                dead: false,
                dead_wm: crate::time::TIME_MIN,
                replay: Vec::new(),
                armed_kill: false,
                slow_us: 0,
                in_flight: false,
                staged_mark: 0,
            };
            let pin = opts.worker_cores.get(id).copied();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-{id}", def.name))
                    .spawn(move || {
                        if let Some(core) = pin {
                            crate::runtime::placement::pin_current(core);
                        }
                        worker.run()
                    })
                    .expect("spawn instance thread"),
            );
        }

        let ingress = io
            .in_sources
            .into_iter()
            .enumerate()
            .map(|(u, src)| StretchIngress::new(src, control.clone(), u))
            .collect();

        (
            VsnEngine {
                control,
                metrics,
                clock,
                esg_in: io.esg_in,
                esg_out: io.esg_out,
                epoch,
                state,
                running,
                health,
                batch_knob,
                threads,
                in_reader_lo: io.reader_base,
                in_reader_hi: io.reader_base + opts.max,
            },
            ingress,
        )
    }

    /// Pending backlog on this stage's ESG_in, restricted to the stage's
    /// own reader-slot group — on a shared fan-out gate a slow *sibling*
    /// stage's entries are not this stage's pending work.
    pub fn in_backlog(&self) -> u64 {
        self.esg_in.backlog_range(self.in_reader_lo, self.in_reader_hi)
    }

    /// Current effective worker batch (tuples per gate synchronization).
    ///
    /// ORDERING: Relaxed — a tuning value acted on by itself.
    pub fn worker_batch(&self) -> usize {
        self.batch_knob.load(Ordering::Relaxed)
    }

    /// Retune the worker batch at runtime (clamped to ≥ 1); workers pick
    /// the new value up at their next gate synchronization. Used by the
    /// harness's adaptive batch sizing: cold stages flush small for
    /// latency, hot stages batch large for throughput.
    ///
    /// ORDERING: Relaxed — no data rides along with the knob; workers
    /// act on whatever value they observe next.
    pub fn set_worker_batch(&self, n: usize) {
        self.batch_knob.store(n.max(1), Ordering::Relaxed);
    }

    /// Current epoch configuration (e, 𝕆, f_μ).
    pub fn epoch_config(&self) -> Arc<EpochConfig> {
        self.epoch.current()
    }

    /// The stage's per-worker health slab: the supervision layer's view
    /// of every instance slot, and the fault-injection surface.
    pub fn health(&self) -> Arc<WorkerHealth> {
        self.health.clone()
    }

    /// The shared state σ (diagnostics / tests).
    pub fn state(&self) -> &Arc<SharedState<L::State>> {
        &self.state
    }

    /// Stop all instance threads and join them.
    pub fn shutdown(&mut self) {
        // ORDERING: Release pairs with the workers' Acquire loop checks.
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl<L: OperatorLogic> Drop for VsnEngine<L> {
    fn drop(&mut self) {
        // ORDERING: Release pairs with the workers' Acquire loop checks.
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One `o+` instance thread.
struct Worker<L: OperatorLogic> {
    core: OperatorCore<L>,
    reader: ReaderHandle<Tuple<L::In>>,
    out: SourceHandle<Tuple<L::Out>>,
    /// Emissions staged for one batched gate add (§Perf): flushed when
    /// full, before every clock publish, and before reconfigurations.
    /// Drawn from the out-gate's buffer pool at spawn, returned at
    /// thread exit (module docs: run-buffer lifecycle).
    out_buf: Vec<Tuple<L::Out>>,
    /// Tuples per gate synchronization, in and out — a cached copy of
    /// `batch_knob`, refreshed once per input batch.
    batch: usize,
    /// Shared live tunable (see [`VsnEngine::set_worker_batch`]).
    batch_knob: Arc<AtomicUsize>,
    epoch: Arc<EpochState>,
    barrier: Arc<EpochBarrier>,
    control: Arc<ControlPlane>,
    running: Arc<AtomicBool>,
    health: Arc<WorkerHealth>,
    cur: Arc<EpochConfig>,
    pending: Option<PendingReconfig>,
    /// Gate slot offsets: instance j ⇔ reader slot `reader_base + j` on
    /// ESG_in and source slot `source_base + j` on ESG_out (shared DAG
    /// gates place each stage's slots at an offset; 0 for private gates).
    reader_base: usize,
    source_base: usize,
    /// Control tuples are broadcast to every reader group on a shared
    /// gate; only specs tagged for this stage are adopted.
    ctrl_tag: u8,
    /// Zombie mode: a caught panic flips this. The worker keeps reading
    /// (so epoch barriers still form and its backlog share stays
    /// GC-accounted) but processes nothing, never beats, and never
    /// advances its out clock — the frozen clock holds the downstream
    /// merge at the death watermark until crash replay runs.
    dead: bool,
    /// The zombie's watermark mirror — `observe` on the (possibly
    /// poisoned) core is off-limits, but delivered tuples are globally
    /// ts-sorted, so a running max is exactly the live trigger condition.
    dead_wm: EventTime,
    /// Crash-replay segments: (first log index, epoch config in force
    /// from that index). Seeded at death with the unprocessed share's
    /// start; extended at every epoch switch the zombie lives through.
    replay: Vec<(u64, Arc<EpochConfig>)>,
    /// Injected kill armed at the last batch boundary: panic at the top
    /// of the next batch, before any tuple is popped.
    armed_kill: bool,
    /// Injected slowdown: sleep this long before each processed batch.
    slow_us: u64,
    /// True while one tuple is popped but not fully stepped — a real
    /// panic mid-tuple must replay that tuple too.
    in_flight: bool,
    /// `out_buf` length at the current tuple's step entry: emissions past
    /// this mark belong to the in-flight tuple and are dropped on a
    /// crash (the replay re-emits them in full).
    staged_mark: usize,
}

impl<L: OperatorLogic> Worker<L>
where
    L::Out: Default,
{
    fn run(&mut self) {
        let mut backoff = Backoff::pooled();
        // Tuples are pulled in batches (one gate synchronization per
        // `self.batch` tuples) and processed newest-last via pop() off
        // the reversed buffer, so `batch.len()` is always the number of
        // retrieved-but-unprocessed tuples — do_reconfig needs it to seed
        // new readers at the tuple currently being processed. The scratch
        // comes from the in-gate's pool (module docs: run-buffer
        // lifecycle) and goes back at thread exit below.
        let mut batch: Vec<Tuple<L::In>> = self.reader.pool().get(self.batch);
        // ORDERING: Acquire pairs with shutdown's Release store.
        while self.running.load(Ordering::Acquire) {
            // adaptive batch sizing: pick up the harness's latest tuning.
            // ORDERING: Relaxed — one uncontended load of a standalone
            // tuning value per gate synchronization.
            self.batch = self.batch_knob.load(Ordering::Relaxed).max(1);
            // burst decay at the batch boundary: a downward retune
            // strands input-scratch capacity, an emission burst strands
            // out_buf capacity; both no-ops in steady state
            pool::shrink_excess(&mut batch, 4 * self.batch);
            pool::shrink_excess(&mut self.out_buf, pool::DEFAULT_SHRINK_CAP);
            if !self.dead {
                self.apply_fault();
            }
            if self.reader.get_batch(&mut batch, self.batch) == 0 {
                if self.dead {
                    // decommissioned zombie: the heal removed this slot
                    // from the gate, nothing is left to drain — exit.
                    if !self.reader.is_active() {
                        break;
                    }
                    backoff.snooze();
                    continue;
                }
                // idle: don't sit on staged emissions
                self.flush_out();
                backoff.snooze();
                continue;
            }
            backoff.reset();
            batch.reverse();
            if self.dead {
                self.drain_dead(&mut batch);
                continue;
            }
            // Containment: an operator panic is caught at batch
            // granularity. The worker enters zombie mode instead of
            // unwinding the thread — a vanished thread would deadlock
            // every future epoch barrier and strand its backlog share.
            if std::panic::catch_unwind(AssertUnwindSafe(|| self.process_batch(&mut batch)))
                .is_err()
            {
                self.enter_dead(&mut batch);
            }
        }
        // hand the run buffers back to the gate pools: whichever worker
        // a later reconfiguration spawns draws them instead of
        // allocating; `put` clears them, so a decommissioned zombie's
        // residue can never alias into a successor's batch
        self.reader.pool().put(std::mem::take(&mut batch));
        let out_buf = std::mem::take(&mut self.out_buf);
        self.out.pool().put(out_buf);
    }

    /// One live input batch: the old `run` inner loop, hoisted so the
    /// panic boundary sits exactly at batch granularity.
    fn process_batch(&mut self, batch: &mut Vec<Tuple<L::In>>) {
        if self.armed_kill {
            self.armed_kill = false;
            panic!("injected fault: kill (worker {})", self.core.id);
        }
        if self.slow_us > 0 {
            // lint: allow(sleep) — injected `Slow` fault: a deliberate
            // wall-clock slowdown IS the behavior under test, not a wait.
            std::thread::sleep(Duration::from_micros(self.slow_us));
        }
        while let Some(t) = batch.pop() {
            // Pool instances activated while parked adopt the installed
            // epoch here (one uncontended atomic load per tuple; active
            // instances update `cur` themselves at the barrier). Checked
            // per tuple, not per batch: the Acquire read of the reader's
            // active flag in get_batch happens-before this load, so a
            // freshly provisioned instance can never process its seed
            // batch under a stale f_μ.
            if self.cur.epoch != self.epoch.epoch_no() {
                self.cur = self.epoch.current();
                self.core.rebuild_expiry_index(&self.cur.mapper);
            }
            self.in_flight = true;
            self.step(t, batch.len());
            self.in_flight = false;
        }
        // one batched downstream add per input batch
        self.flush_out();
        self.health.beat(self.core.id);
    }

    /// Apply a pending injected fault at this batch boundary.
    fn apply_fault(&mut self) {
        match self.health.take_fault(self.core.id) {
            None => {}
            Some(InjectedFault::Kill) => self.armed_kill = true,
            Some(InjectedFault::Slow(us)) => self.slow_us = us,
            Some(InjectedFault::Stall(ms)) => {
                // sleep in slices so shutdown stays responsive; no reads,
                // no beats, no clock advances — exactly what a wedged
                // worker looks like. On resume the worker catches up
                // through the position-deterministic epoch machinery.
                let until = Instant::now() + Duration::from_millis(ms);
                // ORDERING: Acquire pairs with shutdown's Release store.
                while Instant::now() < until && self.running.load(Ordering::Acquire) {
                    // lint: allow(sleep) — injected `Stall` fault: the
                    // wedged wall-clock pause IS the behavior under test.
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    /// A panic escaped the operator: mark this slot dead and switch to
    /// zombie mode. The unprocessed share `[first_unprocessed, …)` is
    /// pinned in the gate log for crash replay at the healing epoch
    /// switch; completed tuples' staged emissions are flushed (they
    /// happened), the in-flight tuple's partial emissions are dropped
    /// (replay re-emits them in full).
    fn enter_dead(&mut self, batch: &mut Vec<Tuple<L::In>>) {
        self.out_buf.truncate(self.staged_mark);
        self.flush_out();
        let first =
            self.reader.cursor().saturating_sub(batch.len() as u64 + u64::from(self.in_flight));
        self.reader.pin_floor(first);
        self.replay.clear();
        self.replay.push((first, self.cur.clone()));
        self.dead = true;
        self.dead_wm = self.core.watermark();
        self.in_flight = false;
        self.health.mark_dead(self.core.id);
        // the batch remainder may hold control/heartbeat tuples this
        // worker must still react to — losing a control tuple here would
        // wedge the stage's next barrier
        self.drain_dead(batch);
    }

    /// Zombie batch drain: adopt controls, track the watermark, trigger
    /// epoch switches — process no data, emit nothing, never beat.
    fn drain_dead(&mut self, batch: &mut Vec<Tuple<L::In>>) {
        while let Some(t) = batch.pop() {
            self.step_dead(&t, batch.len());
        }
    }

    /// The zombie's `step`: the control-plane half of processVSN only.
    /// The watermark mirror is a running max over delivered ts — gate
    /// delivery is globally ts-sorted, so the epoch-switch trigger fires
    /// at exactly the same log index as on every live worker.
    fn step_dead(&mut self, t: &Tuple<L::In>, unconsumed: usize) {
        match &t.kind {
            Kind::Control(spec) => {
                if t.input == self.ctrl_tag && spec.epoch > self.cur.epoch {
                    self.pending = Some(PendingReconfig { spec: spec.clone(), gamma: t.ts });
                }
            }
            Kind::Data | Kind::Heartbeat => {
                if t.ts > self.dead_wm {
                    self.dead_wm = t.ts;
                    if let Some(p) = &self.pending {
                        if self.dead_wm > p.gamma {
                            self.do_reconfig(t, unconsumed);
                        }
                    }
                }
            }
            Kind::Flush | Kind::Dummy => {}
        }
    }

    /// Drain the staged emissions into ESG_out with batched adds
    /// (blocking, with a shutdown escape); drops them silently when this
    /// worker's out-source was decommissioned, like the per-tuple path.
    fn flush_out(&mut self) {
        let mut b = Backoff::active();
        while !self.out_buf.is_empty() {
            match self.out.try_add_batch(&mut self.out_buf) {
                Ok(0) => {
                    // ORDERING: Acquire pairs with shutdown's Release —
                    // the escape hatch out of backpressure at teardown.
                    if !self.running.load(Ordering::Acquire) {
                        self.out_buf.clear();
                        return;
                    }
                    b.snooze();
                }
                Ok(_) => b.reset(),
                Err(crate::scalegate::AddError::Inactive(_)) => {
                    self.out_buf.clear(); // decommissioned
                    return;
                }
                Err(crate::scalegate::AddError::Full(_)) => {
                    unreachable!("try_add_batch signals Full as Ok(0)")
                }
            }
        }
    }

    /// processVSN (Alg. 4) for one delivered tuple. `unconsumed` is the
    /// number of tuples this worker has already taken from the gate but
    /// not yet processed (its batch remainder).
    fn step(&mut self, t: Tuple<L::In>, unconsumed: usize) {
        // crash boundary: emissions staged past this mark belong to the
        // tuple now in flight (see `enter_dead`)
        self.staged_mark = self.out_buf.len();
        match &t.kind {
            Kind::Control(spec) => {
                // prepareReconfig (Alg. 6): adopt only newer epochs, and
                // only specs addressed to THIS stage — a shared fan-out
                // gate broadcasts every consumer stage's control tuples
                // to every reader group (`input` carries the target tag).
                if t.input == self.ctrl_tag && spec.epoch > self.cur.epoch {
                    self.pending = Some(PendingReconfig { spec: spec.clone(), gamma: t.ts });
                }
            }
            Kind::Data | Kind::Heartbeat => {
                let grew = self.core.observe(t.ts);
                if grew {
                    if let Some(p) = &self.pending {
                        if self.core.watermark() > p.gamma {
                            self.do_reconfig(&t, unconsumed);
                        }
                    }
                }
                // split borrows for the emission closure: outputs are
                // staged in out_buf and leave via batched adds (§Perf)
                let out_buf = &mut self.out_buf;
                let staged0 = out_buf.len();
                let mut sink = |o: Tuple<L::Out>| {
                    out_buf.push(o);
                };
                let mut ctx = Ctx::new(&mut sink);
                ctx.ingest_us = t.ingest_us;
                if grew {
                    self.core.advance(&self.cur.mapper, &mut ctx);
                }
                if t.kind.is_data() {
                    self.core.handle_input(&t, &self.cur.mapper, &mut ctx);
                    self.core.metrics.record_in(self.core.id);
                }
                if ctx.comparisons > 0 {
                    self.core.metrics.record_comparisons(ctx.comparisons);
                }
                let emitted = (self.out_buf.len() - staged0) as u64;
                if emitted > 0 {
                    self.core.metrics.record_out(emitted);
                }
                if grew {
                    // implicit watermark to downstream (Lemma 2): all
                    // future emissions carry ts > W. Flush FIRST — the
                    // staged outputs carry ts ≤ W and must enter the gate
                    // before the clock passes them.
                    self.flush_out();
                    self.out.advance_clock(self.core.watermark());
                    if matches!(t.kind, Kind::Heartbeat) {
                        // Forward an explicit heartbeat ENTRY: downstream
                        // *stages* advance their instance watermarks from
                        // delivered tuples, so a clock-only advance would
                        // strand their windows when the rate drops to
                        // zero (§2.3; the egress driver ignores these).
                        self.out_buf.push(Tuple::heartbeat(self.core.watermark()));
                        self.flush_out();
                    }
                } else if self.out_buf.len() >= self.batch {
                    self.flush_out();
                }
            }
            Kind::Flush | Kind::Dummy => {}
        }
    }

    /// The epoch switch (Alg. 4 L17-21), extended with crash replay: a
    /// dead instance leaving the epoch re-processes its unprocessed,
    /// pinned share `[first_unprocessed, S)` under each replay segment's
    /// f_μ before ANY membership change, where S is the trigger tuple's
    /// log index — the same index on every reader, because the switch
    /// fires at the FIRST tuple with ts > γ. Its emissions leave through
    /// its own out source, whose clock froze at the death watermark, so
    /// they still merge downstream in ts order (Lemma 2). A second
    /// barrier then keeps slot removal (and with it gate GC) ordered
    /// after the replay.
    fn do_reconfig(&mut self, t: &Tuple<L::In>, unconsumed: usize) {
        // Staged emissions precede the switch: flush before the barrier
        // so elasticity latency stays batching-independent and the new
        // out-sources (clock floor t.ts) never trail buffered outputs.
        if !self.dead {
            self.flush_out();
        }
        let p = self.pending.take().expect("reconfig without pending spec");
        // barrier over the *current* epoch's instances 𝕆 — zombies keep
        // reading precisely so they arrive here and the barrier forms
        let parties = self.cur.instances.len();
        let leader = self.barrier.wait(parties);
        // install the new epoch config (idempotent across instances)
        let newcfg = self.epoch.install(&p.spec);
        // membership deltas
        let old = &self.cur.instances;
        let joining: Vec<InstanceId> =
            p.spec.instances.iter().copied().filter(|i| !old.contains(i)).collect();
        let leaving: Vec<InstanceId> =
            old.iter().copied().filter(|i| !p.spec.instances.contains(i)).collect();
        // Every party marked dead did so before arriving at the barrier
        // above, so all instances compute the same answer here.
        let dead_leaving =
            leaving.iter().any(|i| self.health.state(*i) == WorkerState::Dead);
        if dead_leaving || (self.dead && !leaving.contains(&self.core.id)) {
            // the trigger tuple's own log index (it is processed under
            // the NEW f_μ by the survivors, so replay excludes it)
            let s_idx = self.reader.cursor().saturating_sub(unconsumed as u64 + 1);
            if self.dead {
                if leaving.contains(&self.core.id) {
                    self.replay_dead(s_idx);
                } else {
                    // the zombie survives this switch: its share of
                    // [S, …) is decided by the NEW mapper — open a new
                    // replay segment at S (S itself included)
                    self.replay.push((s_idx, newcfg.clone()));
                }
            }
            if dead_leaving {
                // hold EVERY instance here until the replay finished:
                // removing the dead slot below would unpin its floor
                // (GC could eat the range) and racing membership against
                // the replayed adds is unordered
                self.barrier.wait(parties);
            }
        }
        let mut performed = false;
        // instance id → gate slot id (shared DAG gates offset each
        // stage's slot ranges; 0-offset for private gates)
        let rd = |ids: &[InstanceId]| -> Vec<usize> {
            ids.iter().map(|i| i + self.reader_base).collect()
        };
        let sr = |ids: &[InstanceId]| -> Vec<usize> {
            ids.iter().map(|i| i + self.source_base).collect()
        };
        if !joining.is_empty() {
            // provision: TB_out sources first, then TB_in readers
            // (Alg. 4 L19); ESG arbitration lets exactly one succeed.
            // New readers start at the tuple *currently being processed*
            // (Theorem 3): our consume cursor is past the whole batch, so
            // the tuple's own index is cursor − unconsumed − 1.
            if self.out.gate().add_sources(&sr(&joining), t.ts) {
                let pos = self.reader.cursor().saturating_sub(unconsumed as u64 + 1);
                self.reader.gate().add_readers_at(&rd(&joining), pos);
                performed = true;
            }
        }
        if !leaving.is_empty() {
            // decommission: TB_in readers first, then TB_out sources
            // (Alg. 4 L20).
            if self.reader.gate().remove_readers(&rd(&leaving)) {
                self.out.gate().remove_sources(&sr(&leaving));
                performed = true;
            }
        }
        if performed || (leader && joining.is_empty() && leaving.is_empty()) {
            self.control.complete(p.spec.epoch);
        }
        self.cur = newcfg;
        if !self.dead {
            // a zombie's core may be poisoned mid-update; it processes no
            // live tuples, so its expiry index is irrelevant anyway
            self.core.rebuild_expiry_index(&self.cur.mapper);
        }
    }

    /// Crash replay (recovery IS reconfiguration): re-process this dead
    /// instance's pinned unprocessed share `[first_unprocessed, end)`,
    /// each segment under the f_μ that governed its index range, through
    /// the plain `handle_input` path — the internal f_μ filter selects
    /// exactly this instance's keys, so this is the same work the live
    /// loop would have done, in the same order. No `observe`/`advance`
    /// during replay: window closes for remapped keys come from their
    /// new owners via the post-switch expiry-index rebuild. Emissions
    /// leave through the zombie's frozen-clock out source and therefore
    /// merge downstream in ts order (delivered ts are sorted, so every
    /// replayed ts ≥ the death watermark the clock froze at).
    ///
    /// A second panic here (a core poisoned beyond replay) abandons the
    /// share — the documented boundary of the fault model.
    fn replay_dead(&mut self, end: u64) {
        let segs = std::mem::take(&mut self.replay);
        let crashed = std::panic::catch_unwind(AssertUnwindSafe(|| {
            for (i, (from, cfg)) in segs.iter().enumerate() {
                // each later segment starts at (and owns) its own epoch
                // switch's trigger index
                let hi = segs.get(i + 1).map_or(end, |next| next.0.min(end));
                for idx in *from..hi {
                    let Some(t) = self.reader.peek(idx) else { break };
                    if !t.kind.is_data() {
                        continue;
                    }
                    let out_buf = &mut self.out_buf;
                    let staged0 = out_buf.len();
                    let mut sink = |o: Tuple<L::Out>| {
                        out_buf.push(o);
                    };
                    let mut ctx = Ctx::new(&mut sink);
                    ctx.ingest_us = t.ingest_us;
                    self.core.handle_input(&t, &cfg.mapper, &mut ctx);
                    self.core.metrics.record_in(self.core.id);
                    let emitted = (self.out_buf.len() - staged0) as u64;
                    if emitted > 0 {
                        self.core.metrics.record_out(emitted);
                    }
                    if self.out_buf.len() >= self.batch {
                        self.flush_out();
                    }
                }
            }
            self.flush_out();
        }))
        .is_err();
        if crashed {
            self.out_buf.clear();
        }
        self.reader.unpin_floor();
    }
}

/// Egress helper: drains an ESG_out reader, recording throughput +
/// latency (now − ingest stamp) like the paper's sink (§8).
pub struct EgressDriver<P: crate::scalegate::GateEntry> {
    reader: crate::scalegate::ReaderHandle<P>,
    /// Drain scratch, drawn from the gate's buffer pool and returned on
    /// drop (§Perf memory discipline).
    batch: Vec<P>,
    pub clock: EngineClock,
    pub count: u64,
    /// Interval histogram — harness loops reset it once per sample.
    pub latency_us: Arc<Histogram>,
    /// Whole-run histogram — never reset by the harness.
    pub latency_total_us: Arc<Histogram>,
}

impl<Out: Clone + Send + Sync + 'static> EgressDriver<Tuple<Out>> {
    pub fn new(reader: crate::scalegate::ReaderHandle<Tuple<Out>>, clock: EngineClock) -> Self {
        let batch = reader.pool().get(WORKER_BATCH);
        EgressDriver {
            reader,
            batch,
            clock,
            count: 0,
            latency_us: Arc::new(Histogram::new()),
            latency_total_us: Arc::new(Histogram::new()),
        }
    }

    /// Drain currently-ready tuples; returns how many were consumed.
    pub fn poll(&mut self) -> usize {
        self.poll_tuples(&mut |_| {})
    }

    /// Like [`poll`](Self::poll) but hands every ready data tuple to `f`.
    pub fn poll_tuples(&mut self, f: &mut dyn FnMut(&Tuple<Out>)) -> usize {
        let mut n = 0;
        loop {
            self.batch.clear();
            if self.reader.get_batch(&mut self.batch, WORKER_BATCH) == 0 {
                break;
            }
            for t in self.batch.drain(..) {
                if t.kind.is_data() {
                    self.count += 1;
                    n += 1;
                    if t.ingest_us > 0 {
                        let lat = self.clock.now_us().saturating_sub(t.ingest_us);
                        self.latency_us.record(lat);
                        self.latency_total_us.record(lat);
                    }
                    f(&t);
                }
            }
        }
        n
    }

    /// Drain until `deadline` or until `expected` tuples were seen.
    pub fn drain_until(&mut self, expected: u64, timeout: std::time::Duration) -> u64 {
        let t0 = Instant::now();
        let mut backoff = Backoff::active();
        while self.count < expected && t0.elapsed() < timeout {
            if self.poll() == 0 {
                backoff.snooze();
            } else {
                backoff.reset();
            }
        }
        self.count
    }
}

impl<P: crate::scalegate::GateEntry> Drop for EgressDriver<P> {
    fn drop(&mut self) {
        // recycle the drain scratch for the gate's next consumer
        self.reader.pool().put(std::mem::take(&mut self.batch));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_beat_advances_progress_and_keeps_live() {
        let h = WorkerHealth::new(2);
        assert_eq!(h.len(), 2);
        assert!(!h.is_empty());
        assert_eq!(h.state(0), WorkerState::Live);
        assert_eq!(h.progress(0), 0);
        h.beat(0);
        h.beat(0);
        assert_eq!(h.progress(0), 2);
        assert_eq!(h.state(0), WorkerState::Live);
        // slot 1 untouched
        assert_eq!(h.progress(1), 0);
    }

    #[test]
    fn health_stall_is_cleared_by_next_beat() {
        let h = WorkerHealth::new(1);
        h.mark_stalled(0);
        assert_eq!(h.state(0), WorkerState::Stalled);
        h.beat(0);
        assert_eq!(h.state(0), WorkerState::Live);
    }

    #[test]
    fn health_dead_is_terminal() {
        let h = WorkerHealth::new(1);
        h.mark_dead(0);
        assert_eq!(h.state(0), WorkerState::Dead);
        // neither a beat nor a stall mark resurrects a dead slot
        h.beat(0);
        assert_eq!(h.state(0), WorkerState::Dead);
        h.mark_stalled(0);
        assert_eq!(h.state(0), WorkerState::Dead);
    }

    #[test]
    fn fault_injection_roundtrips_params() {
        let h = WorkerHealth::new(3);
        h.inject(0, InjectedFault::Kill);
        h.inject(1, InjectedFault::Stall(750));
        h.inject(2, InjectedFault::Slow(12_345));
        assert_eq!(h.take_fault(0), Some(InjectedFault::Kill));
        assert_eq!(h.take_fault(1), Some(InjectedFault::Stall(750)));
        assert_eq!(h.take_fault(2), Some(InjectedFault::Slow(12_345)));
        // pickup clears the pending fault
        assert_eq!(h.take_fault(0), None);
        assert_eq!(h.take_fault(1), None);
        assert_eq!(h.take_fault(2), None);
    }

    #[test]
    fn fault_injection_overwrites_before_pickup() {
        let h = WorkerHealth::new(1);
        h.inject(0, InjectedFault::Stall(100));
        h.inject(0, InjectedFault::Kill);
        assert_eq!(h.take_fault(0), Some(InjectedFault::Kill));
    }

    #[test]
    fn health_snapshot_copies_every_slot() {
        let h = WorkerHealth::new(3);
        h.beat(0);
        h.mark_stalled(1);
        h.mark_dead(2);
        let snap = h.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[0].state, WorkerState::Live);
        assert_eq!(snap[0].progress, 1);
        assert!(snap[0].last_advance_us <= h.now_us());
        assert_eq!(snap[1].state, WorkerState::Stalled);
        assert_eq!(snap[2].state, WorkerState::Dead);
    }
}
