//! The VSN (STRETCH) engine: `setup(O+, m, n)` (§7, Fig. 5).
//!
//! Creates n `o+` instances sharing the state σ, connects m of them to
//! `ESG_in`/`ESG_out` and parks the remaining n−m in the pool. Each
//! instance runs `processVSN` (Alg. 4) on its own thread: poll `ESG_in`,
//! handle control tuples (Alg. 6), trigger epoch switches at the barrier,
//! perform gate membership changes (exactly one instance succeeds — the
//! ESG arbitration), then run the shared [`OperatorCore`].

use crate::engine::barrier::EpochBarrier;
use crate::engine::epoch::{EpochConfig, EpochState, PendingReconfig};
use crate::engine::ingress::{ControlPlane, StretchIngress};
use crate::metrics::{Histogram, OperatorMetrics};
use crate::operator::state::SharedState;
use crate::operator::{Ctx, OperatorCore, OperatorDef, OperatorLogic};
use crate::scalegate::{Esg, EsgConfig, ReaderHandle, SourceHandle};
use crate::tuple::{InstanceId, Kind, Mapper, Tuple};
use crate::util::Backoff;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct VsnOptions {
    /// Initial parallelism degree m.
    pub initial: usize,
    /// Maximum parallelism degree n (pool size = n − m).
    pub max: usize,
    /// Number of upstream instances feeding ESG_in.
    pub upstreams: usize,
    /// Readers on ESG_out (egress or downstream instances).
    pub egress_readers: usize,
    /// Flow-control capacity of each gate (§8's bounded ESG).
    pub gate_capacity: usize,
    /// σ shard count.
    pub shards: usize,
}

impl Default for VsnOptions {
    fn default() -> Self {
        VsnOptions {
            initial: 1,
            max: 4,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: 1 << 15,
            shards: crate::operator::state::DEFAULT_SHARDS,
        }
    }
}

/// Wall-clock origin shared by ingress stampers and egress latency
/// accounting.
#[derive(Clone)]
pub struct EngineClock(Arc<Instant>);

impl EngineClock {
    pub fn new() -> Self {
        EngineClock(Arc::new(Instant::now()))
    }
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

impl Default for EngineClock {
    fn default() -> Self {
        Self::new()
    }
}

/// The running engine; dropping it shuts the instance threads down.
pub struct VsnEngine<L: OperatorLogic> {
    pub control: Arc<ControlPlane>,
    pub metrics: Arc<OperatorMetrics>,
    pub clock: EngineClock,
    pub esg_in: Esg<Tuple<L::In>>,
    pub esg_out: Esg<Tuple<L::Out>>,
    epoch: Arc<EpochState>,
    state: Arc<SharedState<L::State>>,
    running: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl<L: OperatorLogic> VsnEngine<L>
where
    L::In: Default,
{
    /// `setup(O+, m, n)`: build gates, share σ, spawn n instance threads
    /// (m active). Returns the engine plus the upstream ingress wrappers
    /// and the ESG_out readers.
    pub fn setup(
        def: OperatorDef<L>,
        opts: VsnOptions,
    ) -> (Self, Vec<StretchIngress<L::In>>, Vec<ReaderHandle<Tuple<L::Out>>>) {
        assert!(opts.initial >= 1 && opts.initial <= opts.max);
        let (esg_in, in_sources, in_readers) = Esg::new(
            EsgConfig {
                max_sources: opts.upstreams,
                max_readers: opts.max,
                capacity: opts.gate_capacity,
                source_queue: (opts.gate_capacity / opts.upstreams.max(1)).clamp(64, 1 << 14),
            },
            opts.upstreams,
            opts.initial,
        );
        let (esg_out, out_sources, out_readers) = Esg::new(
            EsgConfig {
                max_sources: opts.max,
                max_readers: opts.egress_readers,
                capacity: opts.gate_capacity,
                source_queue: (opts.gate_capacity / opts.max.max(1)).clamp(64, 1 << 14),
            },
            opts.initial,
            opts.egress_readers,
        );
        let state: Arc<SharedState<L::State>> = SharedState::new(opts.shards);
        let metrics = OperatorMetrics::new(opts.max);
        let epoch = EpochState::new(EpochConfig {
            epoch: 0,
            instances: Arc::new((0..opts.initial).collect()),
            mapper: Mapper::hash_mod(opts.initial),
        });
        let control = ControlPlane::new(opts.upstreams, 0);
        let barrier = Arc::new(EpochBarrier::new());
        let running = Arc::new(AtomicBool::new(true));
        let issued: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
        let clock = EngineClock::new();

        let mut threads = Vec::with_capacity(opts.max);
        for (id, (reader, out)) in in_readers.into_iter().zip(out_sources).enumerate() {
            let mut worker = Worker {
                core: OperatorCore::new(def.clone(), id, state.clone(), metrics.clone()),
                reader,
                out,
                epoch: epoch.clone(),
                barrier: barrier.clone(),
                control: control.clone(),
                issued: issued.clone(),
                running: running.clone(),
                cur: epoch.current(),
                pending: None,
            };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-{id}", def.name))
                    .spawn(move || worker.run())
                    .expect("spawn instance thread"),
            );
        }

        let ingress = in_sources
            .into_iter()
            .enumerate()
            .map(|(u, src)| StretchIngress::new(src, control.clone(), u, issued.clone()))
            .collect();

        (
            VsnEngine {
                control,
                metrics,
                clock,
                esg_in,
                esg_out,
                epoch,
                state,
                running,
                threads,
            },
            ingress,
            out_readers,
        )
    }

    /// Current epoch configuration (e, 𝕆, f_μ).
    pub fn epoch_config(&self) -> Arc<EpochConfig> {
        self.epoch.current()
    }

    /// The shared state σ (diagnostics / tests).
    pub fn state(&self) -> &Arc<SharedState<L::State>> {
        &self.state
    }

    /// Stop all instance threads and join them.
    pub fn shutdown(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl<L: OperatorLogic> Drop for VsnEngine<L> {
    fn drop(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One `o+` instance thread.
struct Worker<L: OperatorLogic> {
    core: OperatorCore<L>,
    reader: ReaderHandle<Tuple<L::In>>,
    out: SourceHandle<Tuple<L::Out>>,
    epoch: Arc<EpochState>,
    barrier: Arc<EpochBarrier>,
    control: Arc<ControlPlane>,
    issued: Arc<Mutex<HashMap<u64, Instant>>>,
    running: Arc<AtomicBool>,
    cur: Arc<EpochConfig>,
    pending: Option<PendingReconfig>,
}

impl<L: OperatorLogic> Worker<L> {
    fn run(&mut self) {
        let mut backoff = Backoff::pooled();
        while self.running.load(Ordering::Acquire) {
            // Pool instances (and instances activated while parked) track
            // the installed epoch; active instances update it themselves
            // at the barrier, so this check only fires for pool wake-ups.
            if self.cur.epoch != self.epoch.epoch_no() {
                self.cur = self.epoch.current();
                self.core.rebuild_expiry_index(&self.cur.mapper);
            }
            match self.reader.get() {
                Some(t) => {
                    backoff.reset();
                    self.step(t);
                }
                None => backoff.snooze(),
            }
        }
    }

    /// processVSN (Alg. 4) for one delivered tuple.
    fn step(&mut self, t: Tuple<L::In>) {
        match &t.kind {
            Kind::Control(spec) => {
                // prepareReconfig (Alg. 6): adopt only newer epochs
                if spec.epoch > self.cur.epoch {
                    self.pending = Some(PendingReconfig { spec: spec.clone(), gamma: t.ts });
                }
            }
            Kind::Data | Kind::Heartbeat => {
                let grew = self.core.observe(t.ts);
                if grew {
                    if let Some(p) = &self.pending {
                        if self.core.watermark() > p.gamma {
                            self.do_reconfig(&t);
                        }
                    }
                }
                // split borrows for the emission closure
                let out = &mut self.out;
                let running = &self.running;
                let mut emitted = 0u64;
                let mut sink = |o: Tuple<L::Out>| {
                    emitted += 1;
                    // blocking add with shutdown escape (flow control)
                    let mut v = o;
                    let mut b = Backoff::active();
                    loop {
                        match out.try_add(v) {
                            Ok(()) => break,
                            Err(crate::scalegate::AddError::Inactive(_)) => break, // decommissioned
                            Err(crate::scalegate::AddError::Full(back)) => {
                                if !running.load(Ordering::Acquire) {
                                    break;
                                }
                                v = back;
                                b.snooze();
                            }
                        }
                    }
                };
                let mut ctx = Ctx::new(&mut sink);
                ctx.ingest_us = t.ingest_us;
                if grew {
                    self.core.advance(&self.cur.mapper, &mut ctx);
                }
                if t.kind.is_data() {
                    self.core.handle_input(&t, &self.cur.mapper, &mut ctx);
                    self.core.metrics.record_in(self.core.id);
                }
                if ctx.comparisons > 0 {
                    self.core.metrics.record_comparisons(ctx.comparisons);
                }
                if emitted > 0 {
                    self.core.metrics.record_out(emitted);
                }
                if grew {
                    // implicit watermark to downstream (Lemma 2): all
                    // future emissions carry ts > W
                    self.out.advance_clock(self.core.watermark());
                }
            }
            Kind::Flush | Kind::Dummy => {}
        }
    }

    /// The epoch switch (Alg. 4 L17-21).
    fn do_reconfig(&mut self, t: &Tuple<L::In>) {
        let p = self.pending.take().expect("reconfig without pending spec");
        // barrier over the *current* epoch's instances 𝕆
        let leader = self.barrier.wait(self.cur.instances.len());
        // install the new epoch config (idempotent across instances)
        let newcfg = self.epoch.install(&p.spec);
        // membership deltas
        let old = &self.cur.instances;
        let joining: Vec<InstanceId> =
            p.spec.instances.iter().copied().filter(|i| !old.contains(i)).collect();
        let leaving: Vec<InstanceId> =
            old.iter().copied().filter(|i| !p.spec.instances.contains(i)).collect();
        let mut performed = false;
        if !joining.is_empty() {
            // provision: TB_out sources first, then TB_in readers
            // (Alg. 4 L19); ESG arbitration lets exactly one succeed.
            if self.out.gate().add_sources(&joining, t.ts) {
                self.reader.gate().add_readers(&joining, self.core.id);
                performed = true;
            }
        }
        if !leaving.is_empty() {
            // decommission: TB_in readers first, then TB_out sources
            // (Alg. 4 L20).
            if self.reader.gate().remove_readers(&leaving) {
                self.out.gate().remove_sources(&leaving);
                performed = true;
            }
        }
        if performed || (leader && joining.is_empty() && leaving.is_empty()) {
            if let Some(issued) = self.issued.lock().unwrap().remove(&p.spec.epoch) {
                self.control.record_completion(p.spec.epoch, issued);
            }
        }
        self.cur = newcfg;
        self.core.rebuild_expiry_index(&self.cur.mapper);
    }
}

/// Egress helper: drains an ESG_out reader, recording throughput +
/// latency (now − ingest stamp) like the paper's sink (§8).
pub struct EgressDriver<P: crate::scalegate::GateEntry> {
    reader: crate::scalegate::ReaderHandle<P>,
    pub clock: EngineClock,
    pub count: u64,
    pub latency_us: Arc<Histogram>,
}

impl<Out: Clone + Send + Sync + 'static> EgressDriver<Tuple<Out>> {
    pub fn new(reader: crate::scalegate::ReaderHandle<Tuple<Out>>, clock: EngineClock) -> Self {
        EgressDriver { reader, clock, count: 0, latency_us: Arc::new(Histogram::new()) }
    }

    /// Drain currently-ready tuples; returns how many were consumed.
    pub fn poll(&mut self) -> usize {
        let mut n = 0;
        while let Some(t) = self.reader.get() {
            if t.kind.is_data() {
                self.count += 1;
                n += 1;
                if t.ingest_us > 0 {
                    let now = self.clock.now_us();
                    self.latency_us.record(now.saturating_sub(t.ingest_us));
                }
            }
        }
        n
    }

    /// Drain until `deadline` or until `expected` tuples were seen.
    pub fn drain_until(&mut self, expected: u64, timeout: std::time::Duration) -> u64 {
        let t0 = Instant::now();
        let mut backoff = Backoff::active();
        while self.count < expected && t0.elapsed() < timeout {
            if self.poll() == 0 {
                backoff.snooze();
            } else {
                backoff.reset();
            }
        }
        self.count
    }
}
