//! The VSN (STRETCH) engine: `setup(O+, m, n)` (§7, Fig. 5).
//!
//! Creates n `o+` instances sharing the state σ, connects m of them to
//! `ESG_in`/`ESG_out` and parks the remaining n−m in the pool. Each
//! instance runs `processVSN` (Alg. 4) on its own thread: poll `ESG_in`,
//! handle control tuples (Alg. 6), trigger epoch switches at the barrier,
//! perform gate membership changes (exactly one instance succeeds — the
//! ESG arbitration), then run the shared [`OperatorCore`].
//!
//! Construction is split in two (the pipeline refactor): gate
//! construction ([`VsnOptions::in_gate_config`]/[`VsnOptions::out_gate_config`]
//! + [`Esg::new`]) and worker spawning over externally supplied gate ends
//! ([`VsnEngine::setup_with_gates`]). Two engines can therefore *share* a
//! gate — stage N's ESG_out is stage N+1's ESG_in, the zero-copy hand-off
//! behind [`crate::engine::pipeline`]. [`VsnEngine::setup`] composes the
//! two halves for the classic single-operator shape.

use crate::engine::barrier::EpochBarrier;
use crate::engine::epoch::{EpochConfig, EpochState, PendingReconfig};
use crate::engine::ingress::{ControlPlane, StretchIngress};
use crate::metrics::{Histogram, OperatorMetrics};
use crate::operator::state::SharedState;
use crate::operator::{Ctx, OperatorCore, OperatorDef, OperatorLogic};
use crate::scalegate::{Esg, EsgConfig, ReaderHandle, SourceHandle};
use crate::tuple::{InstanceId, Kind, Mapper, Tuple};
use crate::util::Backoff;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Default tuples a worker takes from ESG_in per gate synchronization
/// (see [`ReaderHandle::get_batch`]) and emits downstream per
/// [`SourceHandle::add_batch`]; also the egress drain granularity.
/// Tunable per engine via [`VsnOptions::worker_batch`] /
/// [`crate::config::BatchTuning`].
pub const WORKER_BATCH: usize = 128;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct VsnOptions {
    /// Initial parallelism degree m.
    pub initial: usize,
    /// Maximum parallelism degree n (pool size = n − m).
    pub max: usize,
    /// Number of upstream instances feeding ESG_in.
    pub upstreams: usize,
    /// Readers on ESG_out (egress or downstream instances).
    pub egress_readers: usize,
    /// Flow-control capacity of each gate (§8's bounded ESG).
    pub gate_capacity: usize,
    /// σ shard count.
    pub shards: usize,
    /// Tuples moved per worker gate synchronization, in and out
    /// ([`ReaderHandle::get_batch`] / [`SourceHandle::add_batch`]).
    pub worker_batch: usize,
    /// Kernel core ids the instance threads pin themselves to (instance
    /// id indexes the list; empty = no pinning). Cover ALL `max` slots,
    /// not just `initial`: pooled instances spawn during the same build
    /// and inherit the spawning thread's affinity mask otherwise. Filled
    /// by a `runtime::placement::PlacementPlan`.
    pub worker_cores: Vec<usize>,
}

impl Default for VsnOptions {
    fn default() -> Self {
        VsnOptions {
            initial: 1,
            max: 4,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: 1 << 15,
            shards: crate::operator::state::DEFAULT_SHARDS,
            worker_batch: WORKER_BATCH,
            worker_cores: Vec::new(),
        }
    }
}

impl VsnOptions {
    /// Apply the `[batch]` section of an experiment config.
    pub fn with_batch(mut self, tuning: &crate::config::BatchTuning) -> Self {
        self.worker_batch = tuning.worker.max(1);
        self
    }
    /// ESG_in geometry: `upstreams` writers, up to `max` worker readers.
    pub fn in_gate_config(&self) -> EsgConfig {
        EsgConfig::for_gate(self.upstreams, self.max, self.gate_capacity)
    }

    /// ESG_out geometry: up to `max` worker writers, `egress_readers`
    /// readers.
    pub fn out_gate_config(&self) -> EsgConfig {
        EsgConfig::for_gate(self.max, self.egress_readers, self.gate_capacity)
    }
}

/// Wall-clock origin shared by ingress stampers and egress latency
/// accounting. Pipelines share ONE clock across all stages so end-to-end
/// latency stamps stay comparable.
#[derive(Clone)]
pub struct EngineClock(Arc<Instant>);

impl EngineClock {
    pub fn new() -> Self {
        EngineClock(Arc::new(Instant::now()))
    }
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

impl Default for EngineClock {
    fn default() -> Self {
        Self::new()
    }
}

/// The gate ends one engine needs: its input gate (with the worker-side
/// readers and any external-source handles) and its output gate (with the
/// worker-side sources). Output *readers* are not part of a stage — they
/// belong to whoever consumes the stage (egress driver or the downstream
/// stage's workers).
pub struct StageIo<L: OperatorLogic> {
    pub esg_in: Esg<Tuple<L::In>>,
    /// External writer endpoints of ESG_in; wrapped into [`StretchIngress`]
    /// (Alg. 5). Empty for mid-pipeline stages — their ESG_in is fed by
    /// the upstream stage's workers, not by external sources.
    pub in_sources: Vec<SourceHandle<Tuple<L::In>>>,
    /// Worker reader endpoints of ESG_in; exactly `opts.max` of them.
    pub in_readers: Vec<ReaderHandle<Tuple<L::In>>>,
    pub esg_out: Esg<Tuple<L::Out>>,
    /// Worker writer endpoints of ESG_out; exactly `opts.max` of them.
    pub out_sources: Vec<SourceHandle<Tuple<L::Out>>>,
    /// Gate slot index of `in_readers[0]`. On a shared fan-out gate each
    /// consumer stage owns a contiguous reader-slot range; instance j of
    /// this stage reads slot `reader_base + j`. 0 for private gates.
    pub reader_base: usize,
    /// Gate slot index of `out_sources[0]`. On a shared fan-in gate each
    /// upstream stage owns a contiguous source-slot range; instance j of
    /// this stage writes slot `source_base + j`. 0 for private gates.
    pub source_base: usize,
    /// This stage's control tag on its (possibly shared) ESG_in: control
    /// tuples are broadcast to every reader group of the gate, so workers
    /// only adopt specs whose `Tuple::input` matches their stage's tag.
    pub ctrl_tag: u8,
}

/// The running engine; dropping it shuts the instance threads down.
pub struct VsnEngine<L: OperatorLogic> {
    pub control: Arc<ControlPlane>,
    pub metrics: Arc<OperatorMetrics>,
    pub clock: EngineClock,
    pub esg_in: Esg<Tuple<L::In>>,
    pub esg_out: Esg<Tuple<L::Out>>,
    epoch: Arc<EpochState>,
    state: Arc<SharedState<L::State>>,
    running: Arc<AtomicBool>,
    /// Live worker-batch tunable: workers re-read it every gate
    /// synchronization, so the harness can resize batches from observed
    /// backlog without a reconfiguration (adaptive batch sizing).
    batch_knob: Arc<AtomicUsize>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// This stage's reader-slot range on ESG_in (`backlog_range` group).
    in_reader_lo: usize,
    in_reader_hi: usize,
}

impl<L: OperatorLogic> VsnEngine<L>
where
    L::In: Default,
    L::Out: Default,
{
    /// `setup(O+, m, n)`: build gates, share σ, spawn n instance threads
    /// (m active). Returns the engine plus the upstream ingress wrappers
    /// and the ESG_out readers.
    pub fn setup(
        def: OperatorDef<L>,
        opts: VsnOptions,
    ) -> (Self, Vec<StretchIngress<L::In>>, Vec<ReaderHandle<Tuple<L::Out>>>) {
        let (esg_in, in_sources, in_readers) =
            Esg::new(opts.in_gate_config(), opts.upstreams, opts.initial);
        let (esg_out, out_sources, out_readers) =
            Esg::new(opts.out_gate_config(), opts.initial, opts.egress_readers);
        let io = StageIo {
            esg_in,
            in_sources,
            in_readers,
            esg_out,
            out_sources,
            reader_base: 0,
            source_base: 0,
            ctrl_tag: 0,
        };
        let (engine, ingress) = Self::setup_with_gates(def, opts, io, EngineClock::new());
        (engine, ingress, out_readers)
    }

    /// The worker-spawning half of `setup`: share σ, spawn the instance
    /// threads over externally constructed gate ends. This is how the
    /// pipeline layer chains stages through ONE shared gate — the caller
    /// builds `io.esg_in`/`io.esg_out` however it likes (fresh, or the
    /// upstream stage's ESG_out) as long as the worker endpoint counts
    /// equal `opts.max`.
    pub fn setup_with_gates(
        def: OperatorDef<L>,
        opts: VsnOptions,
        io: StageIo<L>,
        clock: EngineClock,
    ) -> (Self, Vec<StretchIngress<L::In>>) {
        assert!(opts.initial >= 1 && opts.initial <= opts.max);
        assert_eq!(io.in_readers.len(), opts.max, "need one ESG_in reader per instance slot");
        assert_eq!(io.out_sources.len(), opts.max, "need one ESG_out source per instance slot");
        let state: Arc<SharedState<L::State>> = SharedState::new(opts.shards);
        let metrics = OperatorMetrics::new(opts.max);
        let epoch = EpochState::new(EpochConfig {
            epoch: 0,
            instances: Arc::new((0..opts.initial).collect()),
            mapper: Mapper::hash_mod(opts.initial),
        });
        let control = ControlPlane::new(io.in_sources.len(), 0);
        let barrier = Arc::new(EpochBarrier::new());
        let running = Arc::new(AtomicBool::new(true));

        let batch = opts.worker_batch.max(1);
        let batch_knob = Arc::new(AtomicUsize::new(batch));
        let mut threads = Vec::with_capacity(opts.max);
        for (id, (reader, out)) in io.in_readers.into_iter().zip(io.out_sources).enumerate() {
            debug_assert_eq!(reader.id(), io.reader_base + id, "reader slot range mismatch");
            debug_assert_eq!(out.id(), io.source_base + id, "source slot range mismatch");
            let mut worker = Worker {
                core: OperatorCore::new(def.clone(), id, state.clone(), metrics.clone()),
                reader,
                out,
                out_buf: Vec::with_capacity(batch),
                batch,
                batch_knob: batch_knob.clone(),
                epoch: epoch.clone(),
                barrier: barrier.clone(),
                control: control.clone(),
                running: running.clone(),
                cur: epoch.current(),
                pending: None,
                reader_base: io.reader_base,
                source_base: io.source_base,
                ctrl_tag: io.ctrl_tag,
            };
            let pin = opts.worker_cores.get(id).copied();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-{id}", def.name))
                    .spawn(move || {
                        if let Some(core) = pin {
                            crate::runtime::placement::pin_current(core);
                        }
                        worker.run()
                    })
                    .expect("spawn instance thread"),
            );
        }

        let ingress = io
            .in_sources
            .into_iter()
            .enumerate()
            .map(|(u, src)| StretchIngress::new(src, control.clone(), u))
            .collect();

        (
            VsnEngine {
                control,
                metrics,
                clock,
                esg_in: io.esg_in,
                esg_out: io.esg_out,
                epoch,
                state,
                running,
                batch_knob,
                threads,
                in_reader_lo: io.reader_base,
                in_reader_hi: io.reader_base + opts.max,
            },
            ingress,
        )
    }

    /// Pending backlog on this stage's ESG_in, restricted to the stage's
    /// own reader-slot group — on a shared fan-out gate a slow *sibling*
    /// stage's entries are not this stage's pending work.
    pub fn in_backlog(&self) -> u64 {
        self.esg_in.backlog_range(self.in_reader_lo, self.in_reader_hi)
    }

    /// Current effective worker batch (tuples per gate synchronization).
    pub fn worker_batch(&self) -> usize {
        self.batch_knob.load(Ordering::Relaxed)
    }

    /// Retune the worker batch at runtime (clamped to ≥ 1); workers pick
    /// the new value up at their next gate synchronization. Used by the
    /// harness's adaptive batch sizing: cold stages flush small for
    /// latency, hot stages batch large for throughput.
    pub fn set_worker_batch(&self, n: usize) {
        self.batch_knob.store(n.max(1), Ordering::Relaxed);
    }

    /// Current epoch configuration (e, 𝕆, f_μ).
    pub fn epoch_config(&self) -> Arc<EpochConfig> {
        self.epoch.current()
    }

    /// The shared state σ (diagnostics / tests).
    pub fn state(&self) -> &Arc<SharedState<L::State>> {
        &self.state
    }

    /// Stop all instance threads and join them.
    pub fn shutdown(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl<L: OperatorLogic> Drop for VsnEngine<L> {
    fn drop(&mut self) {
        self.running.store(false, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One `o+` instance thread.
struct Worker<L: OperatorLogic> {
    core: OperatorCore<L>,
    reader: ReaderHandle<Tuple<L::In>>,
    out: SourceHandle<Tuple<L::Out>>,
    /// Emissions staged for one batched gate add (§Perf): flushed when
    /// full, before every clock publish, and before reconfigurations.
    out_buf: Vec<Tuple<L::Out>>,
    /// Tuples per gate synchronization, in and out — a cached copy of
    /// `batch_knob`, refreshed once per input batch.
    batch: usize,
    /// Shared live tunable (see [`VsnEngine::set_worker_batch`]).
    batch_knob: Arc<AtomicUsize>,
    epoch: Arc<EpochState>,
    barrier: Arc<EpochBarrier>,
    control: Arc<ControlPlane>,
    running: Arc<AtomicBool>,
    cur: Arc<EpochConfig>,
    pending: Option<PendingReconfig>,
    /// Gate slot offsets: instance j ⇔ reader slot `reader_base + j` on
    /// ESG_in and source slot `source_base + j` on ESG_out (shared DAG
    /// gates place each stage's slots at an offset; 0 for private gates).
    reader_base: usize,
    source_base: usize,
    /// Control tuples are broadcast to every reader group on a shared
    /// gate; only specs tagged for this stage are adopted.
    ctrl_tag: u8,
}

impl<L: OperatorLogic> Worker<L>
where
    L::Out: Default,
{
    fn run(&mut self) {
        let mut backoff = Backoff::pooled();
        // Tuples are pulled in batches (one gate synchronization per
        // `self.batch` tuples) and processed newest-last via pop() off
        // the reversed buffer, so `batch.len()` is always the number of
        // retrieved-but-unprocessed tuples — do_reconfig needs it to seed
        // new readers at the tuple currently being processed.
        let mut batch: Vec<Tuple<L::In>> = Vec::with_capacity(self.batch);
        while self.running.load(Ordering::Acquire) {
            // adaptive batch sizing: pick up the harness's latest tuning
            // (one uncontended relaxed load per gate synchronization)
            self.batch = self.batch_knob.load(Ordering::Relaxed).max(1);
            if self.reader.get_batch(&mut batch, self.batch) == 0 {
                // idle: don't sit on staged emissions
                self.flush_out();
                backoff.snooze();
                continue;
            }
            backoff.reset();
            batch.reverse();
            while let Some(t) = batch.pop() {
                // Pool instances activated while parked adopt the installed
                // epoch here (one uncontended atomic load per tuple; active
                // instances update `cur` themselves at the barrier). Checked
                // per tuple, not per batch: the Acquire read of the reader's
                // active flag in get_batch happens-before this load, so a
                // freshly provisioned instance can never process its seed
                // batch under a stale f_μ.
                if self.cur.epoch != self.epoch.epoch_no() {
                    self.cur = self.epoch.current();
                    self.core.rebuild_expiry_index(&self.cur.mapper);
                }
                self.step(t, batch.len());
            }
            // one batched downstream add per input batch
            self.flush_out();
        }
    }

    /// Drain the staged emissions into ESG_out with batched adds
    /// (blocking, with a shutdown escape); drops them silently when this
    /// worker's out-source was decommissioned, like the per-tuple path.
    fn flush_out(&mut self) {
        let mut b = Backoff::active();
        while !self.out_buf.is_empty() {
            match self.out.try_add_batch(&mut self.out_buf) {
                Ok(0) => {
                    if !self.running.load(Ordering::Acquire) {
                        self.out_buf.clear();
                        return;
                    }
                    b.snooze();
                }
                Ok(_) => b.reset(),
                Err(crate::scalegate::AddError::Inactive(_)) => {
                    self.out_buf.clear(); // decommissioned
                    return;
                }
                Err(crate::scalegate::AddError::Full(_)) => {
                    unreachable!("try_add_batch signals Full as Ok(0)")
                }
            }
        }
    }

    /// processVSN (Alg. 4) for one delivered tuple. `unconsumed` is the
    /// number of tuples this worker has already taken from the gate but
    /// not yet processed (its batch remainder).
    fn step(&mut self, t: Tuple<L::In>, unconsumed: usize) {
        match &t.kind {
            Kind::Control(spec) => {
                // prepareReconfig (Alg. 6): adopt only newer epochs, and
                // only specs addressed to THIS stage — a shared fan-out
                // gate broadcasts every consumer stage's control tuples
                // to every reader group (`input` carries the target tag).
                if t.input == self.ctrl_tag && spec.epoch > self.cur.epoch {
                    self.pending = Some(PendingReconfig { spec: spec.clone(), gamma: t.ts });
                }
            }
            Kind::Data | Kind::Heartbeat => {
                let grew = self.core.observe(t.ts);
                if grew {
                    if let Some(p) = &self.pending {
                        if self.core.watermark() > p.gamma {
                            self.do_reconfig(&t, unconsumed);
                        }
                    }
                }
                // split borrows for the emission closure: outputs are
                // staged in out_buf and leave via batched adds (§Perf)
                let out_buf = &mut self.out_buf;
                let staged0 = out_buf.len();
                let mut sink = |o: Tuple<L::Out>| {
                    out_buf.push(o);
                };
                let mut ctx = Ctx::new(&mut sink);
                ctx.ingest_us = t.ingest_us;
                if grew {
                    self.core.advance(&self.cur.mapper, &mut ctx);
                }
                if t.kind.is_data() {
                    self.core.handle_input(&t, &self.cur.mapper, &mut ctx);
                    self.core.metrics.record_in(self.core.id);
                }
                if ctx.comparisons > 0 {
                    self.core.metrics.record_comparisons(ctx.comparisons);
                }
                let emitted = (self.out_buf.len() - staged0) as u64;
                if emitted > 0 {
                    self.core.metrics.record_out(emitted);
                }
                if grew {
                    // implicit watermark to downstream (Lemma 2): all
                    // future emissions carry ts > W. Flush FIRST — the
                    // staged outputs carry ts ≤ W and must enter the gate
                    // before the clock passes them.
                    self.flush_out();
                    self.out.advance_clock(self.core.watermark());
                    if matches!(t.kind, Kind::Heartbeat) {
                        // Forward an explicit heartbeat ENTRY: downstream
                        // *stages* advance their instance watermarks from
                        // delivered tuples, so a clock-only advance would
                        // strand their windows when the rate drops to
                        // zero (§2.3; the egress driver ignores these).
                        self.out_buf.push(Tuple::heartbeat(self.core.watermark()));
                        self.flush_out();
                    }
                } else if self.out_buf.len() >= self.batch {
                    self.flush_out();
                }
            }
            Kind::Flush | Kind::Dummy => {}
        }
    }

    /// The epoch switch (Alg. 4 L17-21).
    fn do_reconfig(&mut self, t: &Tuple<L::In>, unconsumed: usize) {
        // Staged emissions precede the switch: flush before the barrier
        // so elasticity latency stays batching-independent and the new
        // out-sources (clock floor t.ts) never trail buffered outputs.
        self.flush_out();
        let p = self.pending.take().expect("reconfig without pending spec");
        // barrier over the *current* epoch's instances 𝕆
        let leader = self.barrier.wait(self.cur.instances.len());
        // install the new epoch config (idempotent across instances)
        let newcfg = self.epoch.install(&p.spec);
        // membership deltas
        let old = &self.cur.instances;
        let joining: Vec<InstanceId> =
            p.spec.instances.iter().copied().filter(|i| !old.contains(i)).collect();
        let leaving: Vec<InstanceId> =
            old.iter().copied().filter(|i| !p.spec.instances.contains(i)).collect();
        let mut performed = false;
        // instance id → gate slot id (shared DAG gates offset each
        // stage's slot ranges; 0-offset for private gates)
        let rd = |ids: &[InstanceId]| -> Vec<usize> {
            ids.iter().map(|i| i + self.reader_base).collect()
        };
        let sr = |ids: &[InstanceId]| -> Vec<usize> {
            ids.iter().map(|i| i + self.source_base).collect()
        };
        if !joining.is_empty() {
            // provision: TB_out sources first, then TB_in readers
            // (Alg. 4 L19); ESG arbitration lets exactly one succeed.
            // New readers start at the tuple *currently being processed*
            // (Theorem 3): our consume cursor is past the whole batch, so
            // the tuple's own index is cursor − unconsumed − 1.
            if self.out.gate().add_sources(&sr(&joining), t.ts) {
                let pos = self.reader.cursor().saturating_sub(unconsumed as u64 + 1);
                self.reader.gate().add_readers_at(&rd(&joining), pos);
                performed = true;
            }
        }
        if !leaving.is_empty() {
            // decommission: TB_in readers first, then TB_out sources
            // (Alg. 4 L20).
            if self.reader.gate().remove_readers(&rd(&leaving)) {
                self.out.gate().remove_sources(&sr(&leaving));
                performed = true;
            }
        }
        if performed || (leader && joining.is_empty() && leaving.is_empty()) {
            self.control.complete(p.spec.epoch);
        }
        self.cur = newcfg;
        self.core.rebuild_expiry_index(&self.cur.mapper);
    }
}

/// Egress helper: drains an ESG_out reader, recording throughput +
/// latency (now − ingest stamp) like the paper's sink (§8).
pub struct EgressDriver<P: crate::scalegate::GateEntry> {
    reader: crate::scalegate::ReaderHandle<P>,
    batch: Vec<P>,
    pub clock: EngineClock,
    pub count: u64,
    /// Interval histogram — harness loops reset it once per sample.
    pub latency_us: Arc<Histogram>,
    /// Whole-run histogram — never reset by the harness.
    pub latency_total_us: Arc<Histogram>,
}

impl<Out: Clone + Send + Sync + 'static> EgressDriver<Tuple<Out>> {
    pub fn new(reader: crate::scalegate::ReaderHandle<Tuple<Out>>, clock: EngineClock) -> Self {
        EgressDriver {
            reader,
            batch: Vec::with_capacity(WORKER_BATCH),
            clock,
            count: 0,
            latency_us: Arc::new(Histogram::new()),
            latency_total_us: Arc::new(Histogram::new()),
        }
    }

    /// Drain currently-ready tuples; returns how many were consumed.
    pub fn poll(&mut self) -> usize {
        self.poll_tuples(&mut |_| {})
    }

    /// Like [`poll`](Self::poll) but hands every ready data tuple to `f`.
    pub fn poll_tuples(&mut self, f: &mut dyn FnMut(&Tuple<Out>)) -> usize {
        let mut n = 0;
        loop {
            self.batch.clear();
            if self.reader.get_batch(&mut self.batch, WORKER_BATCH) == 0 {
                break;
            }
            for t in self.batch.drain(..) {
                if t.kind.is_data() {
                    self.count += 1;
                    n += 1;
                    if t.ingest_us > 0 {
                        let lat = self.clock.now_us().saturating_sub(t.ingest_us);
                        self.latency_us.record(lat);
                        self.latency_total_us.record(lat);
                    }
                    f(&t);
                }
            }
        }
        n
    }

    /// Drain until `deadline` or until `expected` tuples were seen.
    pub fn drain_until(&mut self, expected: u64, timeout: std::time::Duration) -> u64 {
        let t0 = Instant::now();
        let mut backoff = Backoff::active();
        while self.count < expected && t0.elapsed() < timeout {
            if self.poll() == 0 {
                backoff.snooze();
            } else {
                backoff.reset();
            }
        }
        self.count
    }
}
