//! STRETCH ingress: the `addSTRETCH` wrapper (Alg. 5) and the
//! controller-facing `reconfigure` endpoint (§7).
//!
//! Regular tuples and control tuples can both reach `ESG_in`, but each ESG
//! source must stay timestamp-sorted. Each upstream instance therefore
//! owns a *control queue*; `addSTRETCH` drains it before every add,
//! wrapping the pending (e*, 𝕆*, f_μ*) into a control tuple stamped with
//! the last forwarded timestamp τ.

use crate::scalegate::{AddError, SourceHandle};
use crate::time::EventTime;
use crate::tuple::{InstanceId, Mapper, ReconfigSpec, Tuple};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One pending reconfiguration command plus its issue stamp (for the
/// reconfiguration-time metric, §8.4).
#[derive(Clone)]
pub struct ReconfigCmd {
    pub spec: Arc<ReconfigSpec>,
    pub issued: Instant,
}

/// The per-upstream control queues + epoch counter; shared between the
/// controller and the ingress wrappers.
pub struct ControlPlane {
    queues: Vec<Mutex<VecDeque<ReconfigCmd>>>,
    next_epoch: AtomicU64,
    /// Issue stamps of in-flight control tuples, keyed by epoch: stamped
    /// when the control tuple enters the stage's ESG_in (by the ingress
    /// wrapper or a pipeline control injector), consumed by the instance
    /// that completes the reconfiguration.
    issued: Mutex<std::collections::HashMap<u64, Instant>>,
    /// Completed reconfigurations: (epoch, wall ms from issue to done).
    pub completions: Mutex<Vec<(u64, f64)>>,
}

impl ControlPlane {
    pub fn new(upstreams: usize, first_epoch: u64) -> Arc<Self> {
        Arc::new(ControlPlane {
            queues: (0..upstreams).map(|_| Mutex::new(VecDeque::new())).collect(),
            next_epoch: AtomicU64::new(first_epoch + 1),
            issued: Mutex::new(std::collections::HashMap::new()),
            completions: Mutex::new(Vec::new()),
        })
    }

    /// Claim the next epoch id (control injectors build their own specs).
    pub fn allocate_epoch(&self) -> u64 {
        self.next_epoch.fetch_add(1, Ordering::AcqRel)
    }

    /// `reconfigure(𝕆*, f_μ*)`: enqueue the next-epoch parameters on every
    /// upstream's control queue. Returns the new epoch id.
    pub fn reconfigure(&self, instances: Vec<InstanceId>, mapper: Mapper) -> u64 {
        let epoch = self.allocate_epoch();
        let cmd = ReconfigCmd {
            spec: Arc::new(ReconfigSpec { epoch, instances: Arc::new(instances), mapper }),
            issued: Instant::now(),
        };
        for q in &self.queues {
            q.lock().unwrap().push_back(cmd.clone());
        }
        epoch
    }

    /// Stamp the moment epoch `epoch`'s control tuple entered the gate.
    pub fn note_issued(&self, epoch: u64, at: Instant) {
        self.issued.lock().unwrap().insert(epoch, at);
    }

    /// Record completion of epoch `epoch` if its issue stamp is pending
    /// (idempotent across the instances leaving the barrier).
    pub fn complete(&self, epoch: u64) {
        let at = self.issued.lock().unwrap().remove(&epoch);
        if let Some(at) = at {
            self.record_completion(epoch, at);
        }
    }

    /// Record a completed reconfiguration (called by the winning instance).
    pub fn record_completion(&self, epoch: u64, issued: Instant) {
        self.completions
            .lock()
            .unwrap()
            .push((epoch, issued.elapsed().as_secs_f64() * 1e3));
    }

    /// Reconfiguration durations observed so far (epoch, ms).
    pub fn completion_times(&self) -> Vec<(u64, f64)> {
        self.completions.lock().unwrap().clone()
    }

    fn drain(&self, upstream: usize) -> Option<ReconfigCmd> {
        let mut q = self.queues[upstream].lock().unwrap();
        q.pop_front()
    }

    /// Whether upstream `i` has pending control commands (cheap peek).
    fn has_pending(&self, upstream: usize) -> bool {
        !self.queues[upstream].lock().unwrap().is_empty()
    }
}

/// The `addSTRETCH` wrapper around one upstream instance's ESG source
/// (Alg. 5): forwards control tuples (stamped with the last forwarded τ)
/// ahead of data tuples.
pub struct StretchIngress<P: Clone + Default + Send + Sync + 'static> {
    src: SourceHandle<Tuple<P>>,
    control: Arc<ControlPlane>,
    upstream: usize,
    last_ts: EventTime,
}

impl<P: Clone + Default + Send + Sync + 'static> StretchIngress<P> {
    pub fn new(src: SourceHandle<Tuple<P>>, control: Arc<ControlPlane>, upstream: usize) -> Self {
        StretchIngress { src, control, upstream, last_ts: crate::time::TIME_MIN }
    }

    /// Alg. 5: drain pending control commands as control tuples carrying
    /// the last forwarded timestamp, then add the data tuple. If the
    /// underlying source slot was decommissioned, the tuple is handed
    /// back via `Err(Inactive(t))` — the caller re-routes or drops it
    /// deliberately (no silent loss, no abort).
    pub fn add(&mut self, t: Tuple<P>) -> Result<(), AddError<Tuple<P>>> {
        if self.control.has_pending(self.upstream) {
            while let Some(cmd) = self.control.drain(self.upstream) {
                // γ = τ of the last forwarded tuple (TIME_MIN before any —
                // then the first data tuple will trigger immediately).
                let ts = self.last_ts;
                self.control.note_issued(cmd.spec.epoch, cmd.issued);
                // `input` 0: the ingress wrapper always addresses stage 0
                // of its gate (control tags disambiguate consumer stages
                // on shared DAG gates, not logical join inputs).
                let ctrl = Tuple {
                    ts,
                    kind: crate::tuple::Kind::Control(cmd.spec.clone()),
                    input: 0,
                    ingest_us: 0,
                    payload: t.payload.clone(),
                };
                if self.src.add(ctrl).is_err() {
                    // hand the *data* tuple back (the caller's property)
                    return Err(AddError::Inactive(t));
                }
            }
        }
        debug_assert!(t.ts >= self.last_ts, "upstream {} not ts-sorted", self.upstream);
        self.last_ts = t.ts;
        self.src.add(t)
    }

    /// Batched Alg. 5: drain pending control commands FIRST (control
    /// tuples cut ahead of the whole run, stamped with the last forwarded
    /// τ — so a reconfiguration is never delayed behind a data run), then
    /// hand the ts-sorted run to the gate with one batched add. Drains
    /// `run` on success; on `Err(Inactive)` the unconsumed residual stays
    /// in `run` for the caller to re-route or drop deliberately.
    pub fn add_batch(&mut self, run: &mut Vec<Tuple<P>>) -> Result<(), AddError<()>> {
        let Some(first) = run.first() else { return Ok(()) };
        if self.control.has_pending(self.upstream) {
            let probe = first.clone();
            while let Some(cmd) = self.control.drain(self.upstream) {
                let ts = self.last_ts;
                self.control.note_issued(cmd.spec.epoch, cmd.issued);
                let ctrl = Tuple {
                    ts,
                    kind: crate::tuple::Kind::Control(cmd.spec.clone()),
                    input: 0,
                    ingest_us: 0,
                    payload: probe.payload.clone(),
                };
                if self.src.add(ctrl).is_err() {
                    return Err(AddError::Inactive(()));
                }
            }
        }
        debug_assert!(
            run.first().unwrap().ts >= self.last_ts,
            "upstream {} not ts-sorted",
            self.upstream
        );
        self.last_ts = run.last().unwrap().ts;
        self.src.add_batch(run)
    }

    /// Advance this upstream's clock without data (rate drop to zero).
    /// `Err(Inactive)` reports a decommissioned slot (nothing to hand
    /// back — heartbeats carry no data).
    pub fn heartbeat(&mut self, ts: EventTime) -> Result<(), AddError<()>> {
        // control tuples must still flow even without data
        if self.control.has_pending(self.upstream) {
            while let Some(cmd) = self.control.drain(self.upstream) {
                let cts = self.last_ts;
                self.control.note_issued(cmd.spec.epoch, cmd.issued);
                // payload is never read for control tuples
                let mut t: Tuple<P> = Tuple::control(cts, ReconfigSpec {
                    epoch: cmd.spec.epoch,
                    instances: cmd.spec.instances.clone(),
                    mapper: cmd.spec.mapper.clone(),
                });
                t.kind = crate::tuple::Kind::Control(cmd.spec.clone());
                if self.src.add(t).is_err() {
                    return Err(AddError::Inactive(()));
                }
            }
        }
        // Deliver an explicit heartbeat ENTRY (§2.3): instance watermarks
        // advance from delivered tuples, so a clock-only advance would
        // leave windows unexpired when the rate drops to zero.
        if ts > self.last_ts {
            self.last_ts = ts;
            if self.src.add(Tuple::heartbeat(ts)).is_err() {
                return Err(AddError::Inactive(()));
            }
        }
        Ok(())
    }

    pub fn last_ts(&self) -> EventTime {
        self.last_ts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconfigure_enqueues_on_all_upstreams() {
        let cp = ControlPlane::new(3, 0);
        let e = cp.reconfigure(vec![0, 1], Mapper::hash_mod(2));
        assert_eq!(e, 1);
        for u in 0..3 {
            assert!(cp.has_pending(u));
            let cmd = cp.drain(u).unwrap();
            assert_eq!(cmd.spec.epoch, 1);
            assert!(!cp.has_pending(u));
        }
    }

    #[test]
    fn epochs_increase() {
        let cp = ControlPlane::new(1, 5);
        assert_eq!(cp.reconfigure(vec![0], Mapper::hash_mod(1)), 6);
        assert_eq!(cp.reconfigure(vec![0], Mapper::hash_mod(1)), 7);
    }

    #[test]
    fn complete_consumes_issue_stamp_once() {
        let cp = ControlPlane::new(1, 0);
        let e = cp.allocate_epoch();
        cp.note_issued(e, Instant::now());
        cp.complete(e);
        cp.complete(e); // idempotent: second call finds no pending stamp
        assert_eq!(cp.completion_times().len(), 1);
        assert_eq!(cp.completion_times()[0].0, e);
    }

    #[test]
    fn completions_recorded() {
        let cp = ControlPlane::new(1, 0);
        cp.record_completion(1, Instant::now());
        let c = cp.completion_times();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].0, 1);
        assert!(c[0].1 < 1000.0);
    }
}
