//! Execution engines: the VSN (STRETCH) engine and the SN baseline.
//!
//! * [`vsn`] — `setup(O+, m, n)` with shared σ, shared gates, instance
//!   pool, and epoch-based state-transfer-free elasticity (§5-§7);
//! * [`sn`] — the shared-nothing comparison engine (§2.2): dedicated
//!   queues + data duplication + private state;
//! * [`barrier`], [`epoch`], [`ingress`] — the reconfiguration protocol
//!   pieces (Alg. 4 L17-21, Alg. 5, Alg. 6).

pub mod barrier;
pub mod epoch;
pub mod ingress;
pub mod sn;
pub mod vsn;

pub use barrier::EpochBarrier;
pub use epoch::{EpochConfig, EpochState, PendingReconfig};
pub use ingress::{ControlPlane, StretchIngress};
pub use sn::{SnEgress, SnEngine, SnIngress, SnOptions};
pub use vsn::{EgressDriver, EngineClock, VsnEngine, VsnOptions};
