//! Execution engines: the VSN (STRETCH) engine, the SN baseline, and the
//! multi-stage pipeline layer on top.
//!
//! * [`vsn`] — `setup(O+, m, n)` with shared σ, shared gates, instance
//!   pool, and epoch-based state-transfer-free elasticity (§5-§7), split
//!   into gate construction + worker spawning so engines can share gates;
//! * [`pipeline`] — linear topology layer: stages chained through shared
//!   ESGs (stage N's ESG_out ≡ stage N+1's ESG_in), each stage
//!   independently elastic via its own control plane;
//! * [`dag`] — THE topology construction path: fan-out (several reader
//!   groups on one shared ESG_out) and fan-in (one source-slot group per
//!   upstream on a shared ESG_in), with a reserved control slot + tag
//!   per edge so every stage stays independently elastic; linear chains
//!   ([`pipeline`]) and config-built jobs ([`job`]) both reduce to it;
//! * [`job`] — the declarative JobSpec layer: `[topology]`/`[stage.*]`
//!   config sections resolved against the operator registry
//!   ([`crate::workloads::registry`]) into a running topology, with
//!   typed validation errors (cycle, unknown operator, dangling edge,
//!   edge payload-type mismatch);
//! * [`sn`] — the shared-nothing comparison engine (§2.2): dedicated
//!   queues + data duplication + private state;
//! * [`barrier`], [`epoch`], [`ingress`] — the reconfiguration protocol
//!   pieces (Alg. 4 L17-21, Alg. 5, Alg. 6).

pub mod barrier;
pub mod dag;
pub mod epoch;
pub mod ingress;
pub mod job;
pub mod pipeline;
pub mod sn;
pub mod vsn;

pub use barrier::EpochBarrier;
pub use dag::{DagBuilder, DagError, NodeHandle};
pub use job::{BuiltJob, JobError, JobSpec, StageSpec};
pub use epoch::{EpochConfig, EpochState, PendingReconfig};
pub use ingress::{ControlPlane, StretchIngress};
pub use pipeline::{ControlInjector, Pipeline, PipelineBuilder, StageHandle};
pub use sn::{SnEgress, SnEngine, SnIngress, SnOptions};
pub use vsn::{
    EgressDriver, EngineClock, InjectedFault, StageIo, VsnEngine, VsnOptions, WorkerHealth,
    WorkerHealthSnapshot, WorkerState, WORKER_BATCH,
};
