//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options; `--help` text is generated.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
    /// Shared parse path: `Ok(None)` when the flag is absent, one error
    /// format for every malformed value.
    fn parse_flag<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        self.get(name)
            .map(|v| {
                v.parse::<T>()
                    .map_err(|e| format!("invalid value for --{name}: `{v}` ({e})"))
            })
            .transpose()
    }
    fn num_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.parse_flag(name)?.unwrap_or(default))
    }
    /// Numeric getters: a MISSING flag yields the default, but a present,
    /// malformed value is an error naming the offending flag — it must
    /// never be silently swallowed into the default.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        self.num_or(name, default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        self.num_or(name, default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        self.num_or(name, default)
    }
    /// Optional numeric flag: `Ok(None)` when absent.
    pub fn u64_opt(&self, name: &str) -> Result<Option<u64>, String> {
        self.parse_flag(name)
    }
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

/// `result.or_exit()` — print the CLI error to stderr and exit 2, the
/// uniform way binaries surface [`Args`] parse failures.
pub trait OrExit<T> {
    fn or_exit(self) -> T;
}

impl<T> OrExit<T> for Result<T, String> {
    fn or_exit(self) -> T {
        self.unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    }
}

/// CLI specification + parser.
pub struct Cli {
    bin: String,
    about: String,
    opts: Vec<Opt>,
}

impl Cli {
    pub fn new(bin: &str, about: &str) -> Self {
        Cli { bin: bin.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare an option taking a value, with optional default.
    pub fn opt(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(|s| s.to_string()),
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt { name: name.into(), help: help.into(), takes_value: false, default: None });
        self
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.bin, self.about);
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let def = o.default.as_ref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", arg, o.help, def));
        }
        s.push_str("  --help                   show this help\n");
        s
    }

    /// Parse an iterator of arguments (excluding argv[0]). On `--help`,
    /// prints help and exits.
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            // `cargo bench` appends `--bench` to harness=false binaries
            if a == "--bench" {
                continue;
            }
            if a == "--help" || a == "-h" {
                print!("{}", self.help());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", self.help()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    out.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse `std::env::args().skip(1)`.
    pub fn parse(&self) -> Result<Args, String> {
        self.parse_from(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("threads", "thread count", Some("4"))
            .opt("mode", "run mode", None)
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Args {
        cli().parse_from(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("threads", 0).unwrap(), 4);
        assert!(a.get("mode").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--threads", "8", "--mode=sim"]);
        assert_eq!(a.usize_or("threads", 0).unwrap(), 8);
        assert_eq!(a.get("mode"), Some("sim"));
    }

    #[test]
    fn malformed_numeric_value_errors_name_the_flag() {
        let a = parse(&["--threads", "lots"]);
        let e = a.usize_or("threads", 4).unwrap_err();
        assert!(e.contains("--threads") && e.contains("lots"), "unhelpful error: {e}");
        let e = a.u64_or("threads", 4).unwrap_err();
        assert!(e.contains("--threads"), "{e}");
        let a = parse(&["--mode", "fast"]);
        let e = a.f64_or("mode", 1.0).unwrap_err();
        assert!(e.contains("--mode") && e.contains("fast"), "{e}");
    }

    #[test]
    fn missing_flag_still_yields_default_not_error() {
        let a = parse(&[]);
        assert_eq!(a.u64_or("mode", 9).unwrap(), 9);
        assert_eq!(a.f64_or("mode", 2.5).unwrap(), 2.5);
        assert_eq!(a.u64_opt("mode").unwrap(), None);
    }

    #[test]
    fn optional_numeric_flag_parses_or_errors() {
        let a = parse(&["--threads", "12"]);
        assert_eq!(a.u64_opt("threads").unwrap(), Some(12));
        let a = parse(&["--threads", "12x"]);
        assert!(a.u64_opt("threads").unwrap_err().contains("--threads"));
    }

    #[test]
    fn negative_and_overflow_values_error() {
        let a = parse(&["--threads", "-3"]);
        assert!(a.usize_or("threads", 1).is_err(), "negative must not fall back to default");
        let a = parse(&["--threads", "99999999999999999999999999"]);
        assert!(a.u64_or("threads", 1).is_err(), "overflow must not fall back to default");
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["--verbose", "run", "q3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "q3".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let r = cli().parse_from(vec!["--nope".to_string()]);
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = cli().parse_from(vec!["--mode".to_string()]);
        assert!(r.is_err());
    }
}
