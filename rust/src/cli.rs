//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options; `--help` text is generated.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

/// CLI specification + parser.
pub struct Cli {
    bin: String,
    about: String,
    opts: Vec<Opt>,
}

impl Cli {
    pub fn new(bin: &str, about: &str) -> Self {
        Cli { bin: bin.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare an option taking a value, with optional default.
    pub fn opt(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(|s| s.to_string()),
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt { name: name.into(), help: help.into(), takes_value: false, default: None });
        self
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.bin, self.about);
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let def = o.default.as_ref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", arg, o.help, def));
        }
        s.push_str("  --help                   show this help\n");
        s
    }

    /// Parse an iterator of arguments (excluding argv[0]). On `--help`,
    /// prints help and exits.
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            // `cargo bench` appends `--bench` to harness=false binaries
            if a == "--bench" {
                continue;
            }
            if a == "--help" || a == "-h" {
                print!("{}", self.help());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", self.help()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    out.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse `std::env::args().skip(1)`.
    pub fn parse(&self) -> Result<Args, String> {
        self.parse_from(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("threads", "thread count", Some("4"))
            .opt("mode", "run mode", None)
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Args {
        cli().parse_from(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("threads", 0), 4);
        assert!(a.get("mode").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--threads", "8", "--mode=sim"]);
        assert_eq!(a.usize_or("threads", 0), 8);
        assert_eq!(a.get("mode"), Some("sim"));
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["--verbose", "run", "q3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "q3".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let r = cli().parse_from(vec!["--nope".to_string()]);
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = cli().parse_from(vec!["--mode".to_string()]);
        assert!(r.is_err());
    }
}
