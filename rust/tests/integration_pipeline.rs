//! Multi-operator composition (§7: "STRETCH can be used to instantiate
//! many (connected) operators within a query ... the ESG_out of such
//! upstream peer" acts as the downstream's ESG_in).
//!
//! Stage 1: a forwarding O+ (Operator 6 style) over two inputs;
//! Stage 2: a per-key counting A+ consuming stage 1's output stream.
//! A pump thread plays the role of the shared gate hand-off (our engine
//! instances own their gates; composability of the *semantics* — sorted,
//! watermarked, duplication-free streams — is what this validates).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::aggregate::count_per_key_op;
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::util::Rng;
use stretch::workloads::forward_op;

#[test]
fn two_stage_pipeline_preserves_counts() {
    // stage 1: forward (Π=2 → each tuple appears twice downstream)
    let fwd_pi = 2usize;
    let (mut eng1, mut ing1, mut out1) = VsnEngine::setup(
        forward_op::<u64>(fwd_pi),
        VsnOptions { initial: fwd_pi, max: fwd_pi, upstreams: 2, ..Default::default() },
    );
    // stage 2: count per key over tumbling 100-ms windows
    let (mut eng2, mut ing2, mut out2) = VsnEngine::setup(
        count_per_key_op::<Arc<Vec<Key>>, _>("count", WindowSpec::new(100, 100), |t, keys| {
            keys.extend_from_slice(&t.payload)
        }),
        VsnOptions { initial: 2, max: 2, upstreams: 1, ..Default::default() },
    );

    let n = 4_000i64;
    let mut rng = Rng::new(31);
    let keys: Vec<u64> = (0..n).map(|_| rng.gen_range(10)).collect();
    let expected_per_key: BTreeMap<u64, u64> = {
        let mut m = BTreeMap::new();
        for &k in &keys {
            *m.entry(k).or_default() += fwd_pi as u64; // stage-1 fan-out
        }
        m
    };

    // feeders for stage 1 (two logical inputs)
    let keys1 = keys.clone();
    let mut s1a = ing1.remove(0);
    let mut s1b = ing1.remove(0);
    let feeder = std::thread::spawn(move || {
        for (i, &k) in keys1.iter().enumerate() {
            let ts = i as i64;
            if i % 2 == 0 {
                s1a.add(Tuple::data_on(ts, 0, k)).unwrap();
                s1b.heartbeat(ts).unwrap();
            } else {
                s1b.add(Tuple::data_on(ts, 1, k)).unwrap();
                s1a.heartbeat(ts).unwrap();
            }
        }
        s1a.heartbeat(1_000_000).unwrap();
        s1b.heartbeat(1_000_000).unwrap();
    });

    // pump: stage-1 egress → stage-2 ingress (the gate hand-off)
    let mut stage1_reader = out1.remove(0);
    let mut stage2_in = ing2.remove(0);
    let pump = std::thread::spawn(move || {
        let mut forwarded = 0u64;
        let expect = (n as u64) * fwd_pi as u64;
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        let mut last_ts = 0i64;
        while forwarded < expect && std::time::Instant::now() < deadline {
            match stage1_reader.get() {
                Some(t) if t.kind.is_data() => {
                    last_ts = t.ts;
                    stage2_in.add(Tuple::data(t.ts, Arc::new(vec![t.payload]))).unwrap();
                    forwarded += 1;
                }
                Some(t) => {
                    last_ts = last_ts.max(t.ts);
                }
                None => std::thread::sleep(Duration::from_micros(100)),
            }
        }
        stage2_in.heartbeat(2_000_000).unwrap();
        forwarded
    });

    // collect stage-2 counts
    let mut got: BTreeMap<u64, u64> = BTreeMap::new();
    let mut reader2 = out2.remove(0);
    let deadline = std::time::Instant::now() + Duration::from_secs(40);
    let want_total: u64 = expected_per_key.values().sum();
    let mut total = 0u64;
    while total < want_total && std::time::Instant::now() < deadline {
        match reader2.get() {
            Some(t) if t.kind.is_data() => {
                *got.entry(t.payload.0).or_default() += t.payload.1;
                total += t.payload.1;
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(100)),
        }
    }
    feeder.join().unwrap();
    let pumped = pump.join().unwrap();
    eng1.shutdown();
    eng2.shutdown();
    assert_eq!(pumped, (n as u64) * fwd_pi as u64, "stage-1 fan-out wrong");
    assert_eq!(got, expected_per_key, "end-to-end per-key totals diverged");
}

#[test]
fn pipeline_stage1_reconfig_transparent_downstream() {
    // Reconfigure stage 1 mid-stream; stage 2's totals must be unaffected
    // (Lemma 3: consistent watermarks to downstream peers).
    let (mut eng1, mut ing1, mut out1) = VsnEngine::setup(
        forward_op::<u64>(1),
        VsnOptions { initial: 1, max: 3, upstreams: 1, ..Default::default() },
    );
    let control = eng1.control.clone();
    let n = 3_000i64;
    let mut s1 = ing1.remove(0);
    let feeder = std::thread::spawn(move || {
        for i in 0..n {
            if i == n / 2 {
                control.reconfigure(vec![0, 1, 2], stretch::tuple::Mapper::hash_mod(3));
            }
            s1.add(Tuple::data(i, (i % 7) as u64)).unwrap();
        }
        s1.heartbeat(1_000_000).unwrap();
    });
    // drain stage 1 directly, counting per key and checking sortedness
    let mut reader = out1.remove(0);
    let mut last = i64::MIN;
    let mut count = 0u64;
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    // forward_op with Π(keys)=1 pre-reconfig... each instance forwards every
    // tuple: totals = n*1 before + n*3 after? No: f_MK = {0..n_keys} with
    // n_keys fixed at construction (=1 here), so exactly one instance owns
    // key 0 per epoch → n tuples total, each forwarded exactly once.
    while count < n as u64 && std::time::Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => {
                assert!(t.ts >= last, "downstream stream must stay sorted");
                last = t.ts;
                count += 1;
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(100)),
        }
    }
    feeder.join().unwrap();
    eng1.shutdown();
    assert_eq!(count, n as u64, "forwarding must survive the reconfiguration");
}
