//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-check the compiled Pallas kernels against the rust scalar
//! predicate — the L1 ↔ L3 numerical contract.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) if the
//! artifacts are absent. The whole file is gated on the `pjrt` feature:
//! it drives `xla` types directly, which the default std-only build does
//! not link (see rust/src/runtime/stub.rs).
#![cfg(feature = "pjrt")]

use stretch::runtime::{artifacts_available, artifacts_dir, JoinKernel, PjrtRuntime, BATCH};
use stretch::util::Rng;

fn need_artifacts() -> bool {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return false;
    }
    true
}

/// The rust-side scalar band predicate (the oracle for the kernel).
fn scalar_band(px: f32, py: f32, a: f32, b: f32) -> bool {
    (px - a).abs() <= 10.0 && (py - b).abs() <= 10.0
}

#[test]
fn load_and_run_band_join_artifact() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let exec = rt.load_artifact(&artifacts_dir(), "band_join_b16_w512").unwrap();
    let px = [5.0f32; 16];
    let py = [5.0f32; 16];
    let mut wa = vec![f32::INFINITY; 512];
    let mut wb = vec![f32::INFINITY; 512];
    wa[0] = 10.0; // |5-10| <= 10 → match
    wb[0] = 10.0;
    wa[1] = 50.0; // no match
    wb[1] = 5.0;
    let outs = exec
        .run(&[
            xla::Literal::vec1(&px),
            xla::Literal::vec1(&py),
            xla::Literal::vec1(&wa),
            xla::Literal::vec1(&wb),
        ])
        .unwrap();
    let mask: Vec<i8> = outs[0].to_vec().unwrap();
    let counts: Vec<i32> = outs[1].to_vec().unwrap();
    assert_eq!(mask.len(), 16 * 512);
    assert_eq!(mask[0], 1);
    assert_eq!(mask[1], 0);
    assert_eq!(counts, vec![1i32; 16]);
}

#[test]
fn join_kernel_matches_scalar_predicate() {
    if !need_artifacts() {
        return;
    }
    let mut rng = Rng::new(99);
    let mut kernel = JoinKernel::load().unwrap();
    let mut mask = Vec::new();
    for trial in 0..5 {
        let b = rng.range(1, BATCH + 1);
        let w = rng.range(1, 700);
        let px: Vec<f32> = (0..b).map(|_| rng.f32_range(0.0, 60.0)).collect();
        let py: Vec<f32> = (0..b).map(|_| rng.f32_range(0.0, 60.0)).collect();
        let wa: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 60.0)).collect();
        let wb: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 60.0)).collect();
        kernel.eval_mask(&px, &py, &wa, &wb, &mut mask).unwrap();
        assert_eq!(mask.len(), b * w, "trial {trial}");
        for p in 0..b {
            for i in 0..w {
                let want = scalar_band(px[p], py[p], wa[i], wb[i]);
                assert_eq!(
                    mask[p * w + i] != 0,
                    want,
                    "trial {trial} probe {p} window {i}"
                );
            }
        }
    }
}

#[test]
fn join_kernel_chunks_large_windows() {
    if !need_artifacts() {
        return;
    }
    // window larger than the largest compiled variant (8192) forces the
    // chunked path
    let mut rng = Rng::new(7);
    let mut kernel = JoinKernel::load().unwrap();
    let w = 9000usize;
    let wa: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 100.0)).collect();
    let wb: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 100.0)).collect();
    let mut idx = Vec::new();
    kernel.probe_indices(50.0, 50.0, &wa, &wb, &mut idx).unwrap();
    let expected: Vec<u32> = (0..w)
        .filter(|&i| scalar_band(50.0, 50.0, wa[i], wb[i]))
        .map(|i| i as u32)
        .collect();
    assert_eq!(idx, expected);
    assert!(!idx.is_empty());
}

#[test]
fn window_count_artifact_runs() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let exec = rt.load_artifact(&artifacts_dir(), "window_count_n1024_k1024").unwrap();
    let mut keys = vec![-1i32; 1024];
    keys[0] = 3;
    keys[1] = 3;
    keys[2] = 7;
    let outs = exec.run(&[xla::Literal::vec1(&keys)]).unwrap();
    let counts: Vec<i32> = outs[0].to_vec().unwrap();
    assert_eq!(counts.len(), 1024);
    assert_eq!(counts[3], 2);
    assert_eq!(counts[7], 1);
    assert_eq!(counts.iter().sum::<i32>(), 3);
}

#[test]
fn hedge_artifact_runs() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let exec = rt.load_artifact(&artifacts_dir(), "hedge_b16_w512").unwrap();
    let mut p_nd = [0.0f32; 16];
    let mut p_id = [0i32; 16];
    p_nd[0] = 0.05; // probe: nd=0.05, id=1
    p_id[0] = 1;
    let mut w_nd = vec![0.0f32; 512];
    let mut w_id = vec![-1i32; 512];
    w_nd[0] = -0.05; // ratio -1.0, distinct id → match
    w_id[0] = 2;
    w_nd[1] = -0.05; // same id → no match
    w_id[1] = 1;
    w_nd[2] = 0.05; // same sign → no match
    w_id[2] = 3;
    let outs = exec
        .run(&[
            xla::Literal::vec1(&p_nd),
            xla::Literal::vec1(&p_id),
            xla::Literal::vec1(&w_nd),
            xla::Literal::vec1(&w_id),
        ])
        .unwrap();
    let mask: Vec<i8> = outs[0].to_vec().unwrap();
    assert_eq!(&mask[0..3], &[1, 0, 0]);
}
