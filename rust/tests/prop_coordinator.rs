//! Property tests over the coordinator invariants (randomized via the
//! in-repo testkit; reproduce failures with STRETCH_PROP_SEED).
//!
//! Invariants (DESIGN.md §4):
//! * ESG delivery: every reader sees every ready tuple exactly once, in
//!   non-decreasing ts order, the same order for all readers;
//! * window math: earliest/latest window boundaries match brute force;
//! * SN ≡ VSN: identical output multisets under random workloads;
//! * elasticity: random reconfiguration sequences preserve ScaleJoin's
//!   exact match set (Theorems 3/4).

use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::join::{scalejoin_op, Either, JoinPredicate};
use stretch::scalegate::{scale_gate, Esg, EsgConfig, ReaderHandle};
use stretch::testkit::{check, sorted_timestamps};
use stretch::time::WindowSpec;
use stretch::tuple::{Mapper, Tuple};
use stretch::util::Backoff;

#[test]
fn prop_window_boundaries_match_bruteforce() {
    check("window boundaries", 200, |tc| {
        let wa = tc.rng.range(1, 50) as i64;
        let ws = wa * tc.rng.range(1, 8) as i64;
        let spec = WindowSpec::new(wa, ws);
        let ts = tc.rng.gen_range(10_000) as i64 - 5_000;
        let e = spec.earliest_win_l(ts);
        let l = spec.latest_win_l(ts);
        // brute force: scan aligned boundaries around ts
        let mut brute: Vec<i64> = Vec::new();
        let mut b = ((ts - ws) / wa - 2) * wa;
        while b <= ts + wa {
            if b <= ts && ts < b + ws && b % wa == 0 {
                brute.push(b);
            }
            b += wa;
        }
        assert_eq!(e, *brute.first().unwrap(), "earliest");
        assert_eq!(l, *brute.last().unwrap(), "latest");
    });
}

#[test]
fn prop_esg_same_order_exactly_once() {
    check("esg delivery", 25, |tc| {
        let n_src = tc.rng.range(1, 5);
        let n_rdr = tc.rng.range(1, 4);
        let per_src = tc.rng.range(10, 400);
        let (_g, mut srcs, mut rdrs) =
            scale_gate::<Tuple<(usize, usize)>>(n_src, n_rdr, 1 << 14);
        // interleave sorted streams from all sources on one thread
        let mut streams: Vec<Vec<i64>> = (0..n_src)
            .map(|_| sorted_timestamps(&mut tc.rng, per_src, 0, 4))
            .collect();
        let mut idx = vec![0usize; n_src];
        loop {
            // pick the source with the smallest next ts (keeps per-source order)
            let mut pick = None;
            for s in 0..n_src {
                if idx[s] < streams[s].len() {
                    let ts = streams[s][idx[s]];
                    if pick.map_or(true, |(bts, _)| ts < bts) {
                        pick = Some((ts, s));
                    }
                }
            }
            let Some((ts, s)) = pick else { break };
            srcs[s].add(Tuple::data(ts, (s, idx[s]))).unwrap();
            idx[s] += 1;
        }
        for s in srcs.iter_mut() {
            s.advance_clock(i64::MAX / 8);
        }
        streams.iter_mut().for_each(|v| v.clear());
        let total = per_src * n_src;
        let mut seqs: Vec<Vec<(i64, (usize, usize))>> = Vec::new();
        for r in rdrs.iter_mut() {
            let mut seq = Vec::with_capacity(total);
            let mut backoff = Backoff::active();
            while seq.len() < total {
                match r.get() {
                    Some(t) => {
                        seq.push((t.ts, t.payload));
                        backoff.reset();
                    }
                    None => backoff.snooze(),
                }
            }
            seqs.push(seq);
        }
        // identical sequence for all readers, sorted, exactly-once
        for s in &seqs[1..] {
            assert_eq!(s, &seqs[0], "readers diverged");
        }
        assert!(seqs[0].windows(2).all(|w| w[0].0 <= w[1].0), "ts order violated");
        let mut ids: Vec<(usize, usize)> = seqs[0].iter().map(|&(_, p)| p).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "duplicate or lost tuples");
    });
}

#[test]
fn prop_esg_membership_ops_preserve_order() {
    check("esg elastic membership", 15, |tc| {
        let (g, mut srcs, mut rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
            EsgConfig { max_sources: 3, max_readers: 3, capacity: 1 << 14, source_queue: 4096 },
            2,
            1,
        );
        let n = tc.rng.range(50, 300);
        let mut ts = 0i64;
        let mut seen = Vec::new();
        let mut seq = 0u64;
        let add_reader_at = tc.rng.range(10, n);
        let remove_source_at = tc.rng.range(10, n);
        for i in 0..n {
            ts += tc.rng.gen_range(3) as i64;
            let s = tc.rng.range(0, 2);
            if g.source_active(s) {
                srcs[s].add(Tuple::data(ts, seq)).unwrap();
                seq += 1;
            }
            if i == add_reader_at {
                assert!(g.add_readers(&[1], 0));
            }
            if i == remove_source_at {
                g.remove_sources(&[1]);
            }
            while let Some(t) = rdrs[0].get() {
                seen.push(t.ts);
            }
        }
        srcs[0].advance_clock(i64::MAX / 8);
        while let Some(t) = rdrs[0].get() {
            seen.push(t.ts);
        }
        assert!(seen.windows(2).all(|w| w[0] <= w[1]), "order violated across membership ops");
        // the added reader sees a sorted suffix too
        let mut r1 = Vec::new();
        while let Some(t) = rdrs[1].get() {
            r1.push(t.ts);
        }
        assert!(r1.windows(2).all(|w| w[0] <= w[1]));
    });
}

// --- batched data plane ≡ per-tuple data plane ------------------------

/// One step of the scripted gate workload. Timestamps are globally
/// unique and strictly increasing across the script, so the merged log
/// order is fully determined and the per-tuple and batched executions
/// must produce *identical* per-reader sequences.
#[derive(Clone, Debug)]
enum GateOp {
    Add { src: usize, ts: i64, seq: u64 },
    Drain { max: usize },
    AddSource { src: usize, floor: i64 },
    RemoveSource { src: usize },
    AddReader,
    RemoveReader,
}

fn drain_gate_readers(
    rdrs: &mut [ReaderHandle<Tuple<u64>>],
    active: &[bool; 2],
    seqs: &mut [Vec<(i64, u64)>; 2],
    batched: bool,
    max: usize,
) {
    for i in 0..2 {
        if !active[i] {
            continue;
        }
        if batched {
            let mut buf: Vec<Tuple<u64>> = Vec::new();
            while rdrs[i].get_batch(&mut buf, max) > 0 {
                for t in buf.drain(..) {
                    seqs[i].push((t.ts, t.payload));
                }
            }
        } else {
            while let Some(t) = rdrs[i].get() {
                seqs[i].push((t.ts, t.payload));
            }
        }
    }
}

/// Execute the script on a fresh gate. `batched: false` uses
/// `add`/`get`, `batched: true` uses `add_batch` (runs buffered per
/// source) and `get_batch`.
fn run_gate_script(script: &[GateOp], batched: bool) -> [Vec<(i64, u64)>; 2] {
    let (g, mut srcs, mut rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
        EsgConfig { max_sources: 4, max_readers: 2, capacity: 1 << 14, source_queue: 4096 },
        2,
        1,
    );
    let mut seqs: [Vec<(i64, u64)>; 2] = [Vec::new(), Vec::new()];
    let mut reader_active = [true, false];
    let mut pending: Vec<Vec<Tuple<u64>>> = (0..4).map(|_| Vec::new()).collect();
    for op in script {
        match op {
            GateOp::Add { src, ts, seq } => {
                let t = Tuple::data(*ts, *seq);
                if batched {
                    pending[*src].push(t);
                    if pending[*src].len() >= 9 {
                        srcs[*src].add_batch(&mut pending[*src]).unwrap();
                    }
                } else {
                    srcs[*src].add(t).unwrap();
                }
            }
            GateOp::Drain { max } => {
                if batched {
                    for (s, buf) in pending.iter_mut().enumerate() {
                        if !buf.is_empty() {
                            srcs[s].add_batch(buf).unwrap();
                        }
                    }
                }
                drain_gate_readers(&mut rdrs, &reader_active, &mut seqs, batched, *max);
            }
            GateOp::AddSource { src, floor } => {
                assert!(g.add_sources(&[*src], *floor));
            }
            GateOp::RemoveSource { src } => {
                if batched && !pending[*src].is_empty() {
                    srcs[*src].add_batch(&mut pending[*src]).unwrap();
                }
                assert!(g.remove_sources(&[*src]));
            }
            GateOp::AddReader => {
                // the script drains fully right before, so reader 0's
                // cursor (and hence the seed position) is identical in
                // both executions
                assert!(g.add_readers(&[1], 0));
                reader_active[1] = true;
            }
            GateOp::RemoveReader => {
                assert!(g.remove_readers(&[1]));
                reader_active[1] = false;
            }
        }
    }
    for (s, buf) in pending.iter_mut().enumerate() {
        if batched && !buf.is_empty() {
            srcs[s].add_batch(buf).unwrap();
        }
    }
    for s in 0..4 {
        if g.source_active(s) {
            srcs[s].advance_clock(i64::MAX / 8);
        }
    }
    drain_gate_readers(&mut rdrs, &reader_active, &mut seqs, batched, 33);
    seqs
}

#[test]
fn prop_batched_path_matches_per_tuple_path() {
    check("batched ≡ per-tuple", 25, |tc| {
        // script generation: 2 active sources (0,1), pool 2-3; reader 1
        // joins (and may leave) mid-run; ts strictly increasing ⇒ unique
        let n_ops = tc.rng.range(100, 600);
        let mut script = Vec::with_capacity(n_ops + 8);
        let mut ts = 0i64;
        let mut seq = 0u64;
        let mut active: Vec<usize> = vec![0, 1];
        let mut next_pool = 2usize;
        let mut reader1_state = 0u8; // 0 = never added, 1 = active, 2 = removed
        for _ in 0..n_ops {
            let r = tc.rng.gen_range(100);
            if r < 70 {
                let s = active[tc.rng.range(0, active.len())];
                ts += 1 + tc.rng.gen_range(3) as i64;
                script.push(GateOp::Add { src: s, ts, seq });
                seq += 1;
            } else if r < 82 {
                script.push(GateOp::Drain { max: tc.rng.range(1, 64) });
            } else if r < 87 && active.len() > 1 {
                let s = active.remove(tc.rng.range(0, active.len()));
                script.push(GateOp::Drain { max: 8 });
                script.push(GateOp::RemoveSource { src: s });
            } else if r < 92 && next_pool < 4 {
                script.push(GateOp::AddSource { src: next_pool, floor: ts });
                active.push(next_pool);
                next_pool += 1;
            } else if r < 96 && reader1_state == 0 {
                script.push(GateOp::Drain { max: 16 });
                script.push(GateOp::AddReader);
                reader1_state = 1;
            } else if reader1_state == 1 {
                script.push(GateOp::Drain { max: 16 });
                script.push(GateOp::RemoveReader);
                reader1_state = 2;
            }
        }
        let per_tuple = run_gate_script(&script, false);
        let batched = run_gate_script(&script, true);
        for i in 0..2 {
            assert_eq!(
                per_tuple[i], batched[i],
                "seed {:#x}: reader {i} diverged between per-tuple and batched",
                tc.seed
            );
        }
        // Definition 6 on the shared prefix: sorted, exactly-once
        assert!(per_tuple[0].windows(2).all(|w| w[0].0 < w[1].0), "ts order/uniqueness violated");
        let mut ids: Vec<u64> = per_tuple[0].iter().map(|&(_, p)| p).collect();
        ids.dedup();
        assert_eq!(ids.len(), per_tuple[0].len(), "duplicate delivery");
    });
}

#[test]
fn prop_batched_concurrent_exactly_once_same_order() {
    check("batched concurrent delivery", 4, |tc| {
        let n = 15_000u64; // per source
        let (_g, srcs, rdrs) = scale_gate::<Tuple<u64>>(2, 2, 1 << 15);
        let run_seed = tc.seed;
        let producers: Vec<_> = srcs
            .into_iter()
            .take(2)
            .map(|mut s| {
                std::thread::spawn(move || {
                    let sid = s.id() as u64;
                    let mut rng = stretch::util::Rng::new(run_seed ^ (sid + 1));
                    let mut run: Vec<Tuple<u64>> = Vec::new();
                    let mut i = 0u64;
                    while i < n {
                        let len = 1 + rng.gen_range(40) as u64;
                        for _ in 0..len.min(n - i) {
                            // globally unique, per-source sorted ts
                            let ts = (2 * i + sid) as i64;
                            run.push(Tuple::data(ts, ts as u64));
                            i += 1;
                        }
                        s.add_batch(&mut run).unwrap();
                    }
                    s.advance_clock(i64::MAX / 8);
                })
            })
            .collect();
        let readers: Vec<_> = rdrs
            .into_iter()
            .take(2)
            .map(|mut r| {
                std::thread::spawn(move || {
                    let total = 2 * n as usize;
                    let mut got: Vec<u64> = Vec::with_capacity(total);
                    let mut buf: Vec<Tuple<u64>> = Vec::new();
                    let mut backoff = Backoff::active();
                    while got.len() < total {
                        if r.get_batch(&mut buf, 57) == 0 {
                            backoff.snooze();
                            continue;
                        }
                        backoff.reset();
                        for t in buf.drain(..) {
                            got.push(t.payload);
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let expect: Vec<u64> = (0..2 * n).collect();
        for h in readers {
            let got = h.join().unwrap();
            assert_eq!(got, expect, "seed {:#x}: batched delivery diverged", tc.seed);
        }
    });
}

// --- randomized elastic ScaleJoin vs brute force ----------------------

struct Band;
impl JoinPredicate for Band {
    type L = (i32, f32);
    type R = (i32, f32);
    type Out = (i32, i32);
    fn matches(&self, l: &(i32, f32), r: &(i32, f32)) -> bool {
        (l.0 - r.0).abs() <= 10 && (l.1 - r.1).abs() <= 10.0
    }
    fn combine(&self, l: &(i32, f32), r: &(i32, f32)) -> (i32, i32) {
        (l.0, r.0)
    }
}
type SjIn = Either<(i32, f32), (i32, f32)>;

#[test]
fn prop_random_reconfigs_preserve_join_semantics() {
    check("elastic scalejoin", 6, |tc| {
        let n = tc.rng.range(400, 1200);
        let ws = tc.rng.range(20, 120) as i64;
        let max = 4usize;
        // workload
        let mut ts = 0i64;
        let tuples: Vec<Tuple<SjIn>> = (0..n)
            .map(|_| {
                ts += tc.rng.gen_range(2) as i64;
                let v = (tc.rng.gen_range(30) as i32, tc.rng.gen_range(30) as f32);
                if tc.rng.chance(0.5) {
                    Tuple::data_on(ts, 0, Either::L(v))
                } else {
                    Tuple::data_on(ts, 1, Either::R(v))
                }
            })
            .collect();
        // oracle
        let pred = Band;
        let mut oracle = Vec::new();
        for i in 0..tuples.len() {
            for j in 0..i {
                let (a, b) = (&tuples[i], &tuples[j]);
                if (a.ts - b.ts).abs() >= ws {
                    continue;
                }
                match (&a.payload, &b.payload) {
                    (Either::L(l), Either::R(r)) | (Either::R(r), Either::L(l)) => {
                        if pred.matches(l, r) {
                            oracle.push(pred.combine(l, r));
                        }
                    }
                    _ => {}
                }
            }
        }
        oracle.sort();
        // random reconfiguration plan: 0-3 switches to random subsets
        let n_rc = tc.rng.range(0, 4);
        let mut rc_points: Vec<usize> = (0..n_rc).map(|_| tc.rng.range(50, n - 20)).collect();
        rc_points.sort_unstable();
        rc_points.dedup();
        let rcs: Vec<(usize, Vec<usize>)> = rc_points
            .into_iter()
            .map(|at| {
                let k = tc.rng.range(1, max + 1);
                let mut ids: Vec<usize> = (0..max).collect();
                tc.rng.shuffle(&mut ids);
                ids.truncate(k);
                ids.sort_unstable();
                (at, ids)
            })
            .collect();
        // run
        let def = scalejoin_op("prop-sj", ws, Band, 32);
        let initial = tc.rng.range(1, max + 1);
        let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
            def,
            VsnOptions { initial, max, upstreams: 1, ..Default::default() },
        );
        let control = engine.control.clone();
        let mut ing = ingress.remove(0);
        let feed = tuples.clone();
        let feeder = std::thread::spawn(move || {
            let mut next = 0usize;
            for (i, t) in feed.into_iter().enumerate() {
                if next < rcs.len() && rcs[next].0 == i {
                    let set = rcs[next].1.clone();
                    control.reconfigure(set.clone(), Mapper::over(set));
                    next += 1;
                }
                ing.add(t).unwrap();
            }
            ing.heartbeat(10_000_000).unwrap();
        });
        let mut got = Vec::new();
        let mut reader = readers.remove(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while got.len() < oracle.len() && std::time::Instant::now() < deadline {
            match reader.get() {
                Some(t) if t.kind.is_data() => got.push(t.payload),
                Some(_) => {}
                None => std::thread::sleep(Duration::from_micros(100)),
            }
        }
        feeder.join().unwrap();
        engine.shutdown();
        got.sort();
        assert_eq!(got, oracle, "seed {:#x}: match set diverged", tc.seed);
    });
}

// --- scripted diamond DAG ≡ per-tuple linear reference ----------------

/// Gate-level diamond: one external source gate G1 fanning out to two
/// "stages" A (reader slots 0-1, transform v → 2v) and B (reader slots
/// 2-3, transform v → 2v+1), whose emissions fan in through G2 (A's
/// source slots 0-1, B's 2-3) to a single output reader. Because every
/// A slot id < every B slot id and timestamps are unique, the fan-in
/// merge order is FULLY determined: for each input tuple, A's output
/// precedes B's — so the DAG output must equal, as an exact sequence,
/// the trivial per-tuple linear reference `[2v, 2v+1]` per input, no
/// matter how instances are added/removed per stage mid-run.
#[test]
fn prop_scripted_diamond_dag_matches_per_tuple_linear_reference() {
    use stretch::scalegate::SourceHandle;

    const A_BASE: usize = 0; // A's slots: 0-1
    const B_BASE: usize = 2; // B's slots: 2-3
    const PER_STAGE: usize = 2;

    struct Stage {
        /// Reader handles on G1 (one per slot of this stage's range).
        readers: Vec<ReaderHandle<Tuple<u64>>>,
        /// Source handles on G2, same slot count.
        sources: Vec<SourceHandle<Tuple<u64>>>,
        /// Locally active instance offsets (0-based within the stage).
        active: Vec<usize>,
        /// Gate slot offsets of this stage's ranges.
        rdr_base: usize,
        src_base: usize,
        /// Last input ts this stage has fully drained (its watermark).
        wm: i64,
    }

    impl Stage {
        /// Drain G1 fully; each ACTIVE instance takes everything, emits
        /// the transform of the tuples routed to it into ITS G2 slot,
        /// then advances its G2 clock to the drained watermark.
        fn drain(&mut self, f: impl Fn(u64) -> u64) {
            let active = self.active.clone();
            let mut emitted: Vec<Vec<Tuple<u64>>> = vec![Vec::new(); self.readers.len()];
            let mut buf: Vec<Tuple<u64>> = Vec::new();
            for &k in &active {
                loop {
                    buf.clear();
                    if self.readers[k].get_batch(&mut buf, 64) == 0 {
                        break;
                    }
                    for t in &buf {
                        self.wm = self.wm.max(t.ts);
                        // deterministic exactly-once routing over the
                        // CURRENT active set (membership only changes
                        // between fully drained script points)
                        let owner = active[(t.payload % active.len() as u64) as usize];
                        if owner == k {
                            emitted[k].push(Tuple::data(t.ts, f(t.payload)));
                        }
                    }
                }
            }
            for &k in &active {
                if !emitted[k].is_empty() {
                    self.sources[k].add_batch(&mut emitted[k]).unwrap();
                }
                self.sources[k].advance_clock(self.wm);
            }
        }

        fn add_instance(&mut self, g1: &Esg<Tuple<u64>>, g2: &Esg<Tuple<u64>>, k: usize) {
            assert!(!self.active.contains(&k));
            // seed the new reader at an existing member's position (all
            // equal after a full drain) and the new source at the
            // stage's watermark (Lemma 3 floor)
            let pos = self.readers[self.active[0]].cursor();
            assert!(g1.add_readers_at(&[self.rdr_base + k], pos));
            assert!(g2.add_sources(&[self.src_base + k], self.wm));
            self.active.push(k);
            self.active.sort_unstable();
        }

        fn remove_instance(&mut self, g1: &Esg<Tuple<u64>>, g2: &Esg<Tuple<u64>>, k: usize) {
            assert!(self.active.len() > 1);
            assert!(g1.remove_readers(&[self.rdr_base + k]));
            assert!(g2.remove_sources(&[self.src_base + k]));
            self.active.retain(|&x| x != k);
        }
    }

    check("scripted diamond dag", 20, |tc| {
        // G1: 1 external source, 4 reader slots (A: 0-1, B: 2-3)
        let (g1, mut ext, rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
            EsgConfig { max_sources: 1, max_readers: 4, capacity: 1 << 15, source_queue: 4096 },
            1,
            0,
        );
        // G2: 4 source slots (A: 0-1, B: 2-3), 1 reader
        let (g2, srcs2, mut out): (Esg<Tuple<u64>>, _, _) = Esg::new(
            EsgConfig { max_sources: 4, max_readers: 1, capacity: 1 << 15, source_queue: 4096 },
            0,
            1,
        );
        // initial activation: one instance per stage, output reader 0
        assert!(g1.add_readers_at(&[A_BASE, B_BASE], 0));
        assert!(g2.add_sources(&[A_BASE, B_BASE], stretch::time::TIME_MIN));

        let mut rdrs = rdrs;
        let mut srcs2 = srcs2;
        // split handles into the two stages (readers/sources come out in
        // slot order)
        let b_readers = rdrs.split_off(PER_STAGE);
        let b_sources = srcs2.split_off(PER_STAGE);
        let mut stage_a = Stage {
            readers: rdrs,
            sources: srcs2,
            active: vec![0],
            rdr_base: A_BASE,
            src_base: A_BASE,
            wm: stretch::time::TIME_MIN,
        };
        let mut stage_b = Stage {
            readers: b_readers,
            sources: b_sources,
            active: vec![0],
            rdr_base: B_BASE,
            src_base: B_BASE,
            wm: stretch::time::TIME_MIN,
        };

        let n = tc.rng.range(80, 400);
        let mut ts = 0i64;
        let mut val = 0u64;
        let mut reference: Vec<(i64, u64)> = Vec::new();
        let mut got: Vec<(i64, u64)> = Vec::new();
        let mut drain_out = |got: &mut Vec<(i64, u64)>, out: &mut Vec<ReaderHandle<Tuple<u64>>>| {
            let mut buf: Vec<Tuple<u64>> = Vec::new();
            while out[0].get_batch(&mut buf, 64) > 0 {
                for t in buf.drain(..) {
                    got.push((t.ts, t.payload));
                }
            }
        };

        for _ in 0..n {
            let r = tc.rng.gen_range(100);
            if r < 60 {
                // feed one tuple; the per-tuple linear reference is
                // simply [A-transform, B-transform] in input order
                ts += 1 + tc.rng.gen_range(3) as i64;
                ext[0].add(Tuple::data(ts, val)).unwrap();
                reference.push((ts, 2 * val));
                reference.push((ts, 2 * val + 1));
                val += 1;
            } else if r < 75 {
                stage_a.drain(|v| 2 * v);
                stage_b.drain(|v| 2 * v + 1);
                drain_out(&mut got, &mut out);
            } else {
                // per-stage membership change at a fully drained point
                stage_a.drain(|v| 2 * v);
                stage_b.drain(|v| 2 * v + 1);
                let stage = if tc.rng.chance(0.5) { &mut stage_a } else { &mut stage_b };
                if stage.active.len() == 1 {
                    let k = 1 - stage.active[0];
                    stage.add_instance(&g1, &g2, k);
                } else {
                    let k = stage.active[tc.rng.range(0, stage.active.len())];
                    stage.remove_instance(&g1, &g2, k);
                }
            }
        }
        // end of stream: flush everything through both stages
        ext[0].advance_clock(i64::MAX / 8);
        stage_a.drain(|v| 2 * v);
        stage_b.drain(|v| 2 * v + 1);
        for &k in &stage_a.active {
            stage_a.sources[k].advance_clock(i64::MAX / 8);
        }
        for &k in &stage_b.active {
            stage_b.sources[k].advance_clock(i64::MAX / 8);
        }
        drain_out(&mut got, &mut out);

        assert_eq!(
            got, reference,
            "seed {:#x}: diamond DAG output diverged from the per-tuple linear reference",
            tc.seed
        );
    });
}
