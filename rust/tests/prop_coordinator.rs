//! Property tests over the coordinator invariants (randomized via the
//! in-repo testkit; reproduce failures with STRETCH_PROP_SEED).
//!
//! Invariants (DESIGN.md §4):
//! * ESG delivery: every reader sees every ready tuple exactly once, in
//!   non-decreasing ts order, the same order for all readers;
//! * window math: earliest/latest window boundaries match brute force;
//! * SN ≡ VSN: identical output multisets under random workloads;
//! * elasticity: random reconfiguration sequences preserve ScaleJoin's
//!   exact match set (Theorems 3/4).

use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::join::{scalejoin_op, Either, JoinPredicate};
use stretch::scalegate::{scale_gate, Esg, EsgConfig};
use stretch::testkit::{check, sorted_timestamps};
use stretch::time::WindowSpec;
use stretch::tuple::{Mapper, Tuple};
use stretch::util::Backoff;

#[test]
fn prop_window_boundaries_match_bruteforce() {
    check("window boundaries", 200, |tc| {
        let wa = tc.rng.range(1, 50) as i64;
        let ws = wa * tc.rng.range(1, 8) as i64;
        let spec = WindowSpec::new(wa, ws);
        let ts = tc.rng.gen_range(10_000) as i64 - 5_000;
        let e = spec.earliest_win_l(ts);
        let l = spec.latest_win_l(ts);
        // brute force: scan aligned boundaries around ts
        let mut brute: Vec<i64> = Vec::new();
        let mut b = ((ts - ws) / wa - 2) * wa;
        while b <= ts + wa {
            if b <= ts && ts < b + ws && b % wa == 0 {
                brute.push(b);
            }
            b += wa;
        }
        assert_eq!(e, *brute.first().unwrap(), "earliest");
        assert_eq!(l, *brute.last().unwrap(), "latest");
    });
}

#[test]
fn prop_esg_same_order_exactly_once() {
    check("esg delivery", 25, |tc| {
        let n_src = tc.rng.range(1, 5);
        let n_rdr = tc.rng.range(1, 4);
        let per_src = tc.rng.range(10, 400);
        let (_g, mut srcs, mut rdrs) =
            scale_gate::<Tuple<(usize, usize)>>(n_src, n_rdr, 1 << 14);
        // interleave sorted streams from all sources on one thread
        let mut streams: Vec<Vec<i64>> = (0..n_src)
            .map(|_| sorted_timestamps(&mut tc.rng, per_src, 0, 4))
            .collect();
        let mut idx = vec![0usize; n_src];
        loop {
            // pick the source with the smallest next ts (keeps per-source order)
            let mut pick = None;
            for s in 0..n_src {
                if idx[s] < streams[s].len() {
                    let ts = streams[s][idx[s]];
                    if pick.map_or(true, |(bts, _)| ts < bts) {
                        pick = Some((ts, s));
                    }
                }
            }
            let Some((ts, s)) = pick else { break };
            srcs[s].add(Tuple::data(ts, (s, idx[s])));
            idx[s] += 1;
        }
        for s in srcs.iter_mut() {
            s.advance_clock(i64::MAX / 8);
        }
        streams.iter_mut().for_each(|v| v.clear());
        let total = per_src * n_src;
        let mut seqs: Vec<Vec<(i64, (usize, usize))>> = Vec::new();
        for r in rdrs.iter_mut() {
            let mut seq = Vec::with_capacity(total);
            let mut backoff = Backoff::active();
            while seq.len() < total {
                match r.get() {
                    Some(t) => {
                        seq.push((t.ts, t.payload));
                        backoff.reset();
                    }
                    None => backoff.snooze(),
                }
            }
            seqs.push(seq);
        }
        // identical sequence for all readers, sorted, exactly-once
        for s in &seqs[1..] {
            assert_eq!(s, &seqs[0], "readers diverged");
        }
        assert!(seqs[0].windows(2).all(|w| w[0].0 <= w[1].0), "ts order violated");
        let mut ids: Vec<(usize, usize)> = seqs[0].iter().map(|&(_, p)| p).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "duplicate or lost tuples");
    });
}

#[test]
fn prop_esg_membership_ops_preserve_order() {
    check("esg elastic membership", 15, |tc| {
        let (g, mut srcs, mut rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
            EsgConfig { max_sources: 3, max_readers: 3, capacity: 1 << 14, source_queue: 4096 },
            2,
            1,
        );
        let n = tc.rng.range(50, 300);
        let mut ts = 0i64;
        let mut seen = Vec::new();
        let mut seq = 0u64;
        let add_reader_at = tc.rng.range(10, n);
        let remove_source_at = tc.rng.range(10, n);
        for i in 0..n {
            ts += tc.rng.gen_range(3) as i64;
            let s = tc.rng.range(0, 2);
            if g.source_active(s) {
                srcs[s].add(Tuple::data(ts, seq));
                seq += 1;
            }
            if i == add_reader_at {
                assert!(g.add_readers(&[1], 0));
            }
            if i == remove_source_at {
                g.remove_sources(&[1]);
            }
            while let Some(t) = rdrs[0].get() {
                seen.push(t.ts);
            }
        }
        srcs[0].advance_clock(i64::MAX / 8);
        while let Some(t) = rdrs[0].get() {
            seen.push(t.ts);
        }
        assert!(seen.windows(2).all(|w| w[0] <= w[1]), "order violated across membership ops");
        // the added reader sees a sorted suffix too
        let mut r1 = Vec::new();
        while let Some(t) = rdrs[1].get() {
            r1.push(t.ts);
        }
        assert!(r1.windows(2).all(|w| w[0] <= w[1]));
    });
}

// --- randomized elastic ScaleJoin vs brute force ----------------------

struct Band;
impl JoinPredicate for Band {
    type L = (i32, f32);
    type R = (i32, f32);
    type Out = (i32, i32);
    fn matches(&self, l: &(i32, f32), r: &(i32, f32)) -> bool {
        (l.0 - r.0).abs() <= 10 && (l.1 - r.1).abs() <= 10.0
    }
    fn combine(&self, l: &(i32, f32), r: &(i32, f32)) -> (i32, i32) {
        (l.0, r.0)
    }
}
type SjIn = Either<(i32, f32), (i32, f32)>;

#[test]
fn prop_random_reconfigs_preserve_join_semantics() {
    check("elastic scalejoin", 6, |tc| {
        let n = tc.rng.range(400, 1200);
        let ws = tc.rng.range(20, 120) as i64;
        let max = 4usize;
        // workload
        let mut ts = 0i64;
        let tuples: Vec<Tuple<SjIn>> = (0..n)
            .map(|_| {
                ts += tc.rng.gen_range(2) as i64;
                let v = (tc.rng.gen_range(30) as i32, tc.rng.gen_range(30) as f32);
                if tc.rng.chance(0.5) {
                    Tuple::data_on(ts, 0, Either::L(v))
                } else {
                    Tuple::data_on(ts, 1, Either::R(v))
                }
            })
            .collect();
        // oracle
        let pred = Band;
        let mut oracle = Vec::new();
        for i in 0..tuples.len() {
            for j in 0..i {
                let (a, b) = (&tuples[i], &tuples[j]);
                if (a.ts - b.ts).abs() >= ws {
                    continue;
                }
                match (&a.payload, &b.payload) {
                    (Either::L(l), Either::R(r)) | (Either::R(r), Either::L(l)) => {
                        if pred.matches(l, r) {
                            oracle.push(pred.combine(l, r));
                        }
                    }
                    _ => {}
                }
            }
        }
        oracle.sort();
        // random reconfiguration plan: 0-3 switches to random subsets
        let n_rc = tc.rng.range(0, 4);
        let mut rc_points: Vec<usize> = (0..n_rc).map(|_| tc.rng.range(50, n - 20)).collect();
        rc_points.sort_unstable();
        rc_points.dedup();
        let rcs: Vec<(usize, Vec<usize>)> = rc_points
            .into_iter()
            .map(|at| {
                let k = tc.rng.range(1, max + 1);
                let mut ids: Vec<usize> = (0..max).collect();
                tc.rng.shuffle(&mut ids);
                ids.truncate(k);
                ids.sort_unstable();
                (at, ids)
            })
            .collect();
        // run
        let def = scalejoin_op("prop-sj", ws, Band, 32);
        let initial = tc.rng.range(1, max + 1);
        let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
            def,
            VsnOptions { initial, max, upstreams: 1, ..Default::default() },
        );
        let control = engine.control.clone();
        let mut ing = ingress.remove(0);
        let feed = tuples.clone();
        let feeder = std::thread::spawn(move || {
            let mut next = 0usize;
            for (i, t) in feed.into_iter().enumerate() {
                if next < rcs.len() && rcs[next].0 == i {
                    let set = rcs[next].1.clone();
                    control.reconfigure(set.clone(), Mapper::over(set));
                    next += 1;
                }
                ing.add(t);
            }
            ing.heartbeat(10_000_000);
        });
        let mut got = Vec::new();
        let mut reader = readers.remove(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while got.len() < oracle.len() && std::time::Instant::now() < deadline {
            match reader.get() {
                Some(t) if t.kind.is_data() => got.push(t.payload),
                Some(_) => {}
                None => std::thread::sleep(Duration::from_micros(100)),
            }
        }
        feeder.join().unwrap();
        engine.shutdown();
        got.sort();
        assert_eq!(got, oracle, "seed {:#x}: match set diverged", tc.seed);
    });
}
