//! Property tests over the coordinator invariants (randomized via the
//! in-repo testkit; reproduce failures with STRETCH_PROP_SEED).
//!
//! Invariants (DESIGN.md §4):
//! * ESG delivery: every reader sees every ready tuple exactly once, in
//!   non-decreasing ts order, the same order for all readers;
//! * window math: earliest/latest window boundaries match brute force;
//! * SN ≡ VSN: identical output multisets under random workloads;
//! * elasticity: random reconfiguration sequences preserve ScaleJoin's
//!   exact match set (Theorems 3/4).

use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::join::{scalejoin_op, Either, JoinPredicate};
use stretch::scalegate::{scale_gate, Esg, EsgConfig, ReaderHandle};
use stretch::testkit::{check, sorted_timestamps};
use stretch::time::WindowSpec;
use stretch::tuple::{Mapper, Tuple};
use stretch::util::Backoff;

#[test]
fn prop_window_boundaries_match_bruteforce() {
    check("window boundaries", 200, |tc| {
        let wa = tc.rng.range(1, 50) as i64;
        let ws = wa * tc.rng.range(1, 8) as i64;
        let spec = WindowSpec::new(wa, ws);
        let ts = tc.rng.gen_range(10_000) as i64 - 5_000;
        let e = spec.earliest_win_l(ts);
        let l = spec.latest_win_l(ts);
        // brute force: scan aligned boundaries around ts
        let mut brute: Vec<i64> = Vec::new();
        let mut b = ((ts - ws) / wa - 2) * wa;
        while b <= ts + wa {
            if b <= ts && ts < b + ws && b % wa == 0 {
                brute.push(b);
            }
            b += wa;
        }
        assert_eq!(e, *brute.first().unwrap(), "earliest");
        assert_eq!(l, *brute.last().unwrap(), "latest");
    });
}

#[test]
fn prop_esg_same_order_exactly_once() {
    check("esg delivery", 25, |tc| {
        let n_src = tc.rng.range(1, 5);
        let n_rdr = tc.rng.range(1, 4);
        let per_src = tc.rng.range(10, 400);
        let (_g, mut srcs, mut rdrs) =
            scale_gate::<Tuple<(usize, usize)>>(n_src, n_rdr, 1 << 14);
        // interleave sorted streams from all sources on one thread
        let mut streams: Vec<Vec<i64>> = (0..n_src)
            .map(|_| sorted_timestamps(&mut tc.rng, per_src, 0, 4))
            .collect();
        let mut idx = vec![0usize; n_src];
        loop {
            // pick the source with the smallest next ts (keeps per-source order)
            let mut pick = None;
            for s in 0..n_src {
                if idx[s] < streams[s].len() {
                    let ts = streams[s][idx[s]];
                    if pick.map_or(true, |(bts, _)| ts < bts) {
                        pick = Some((ts, s));
                    }
                }
            }
            let Some((ts, s)) = pick else { break };
            srcs[s].add(Tuple::data(ts, (s, idx[s])));
            idx[s] += 1;
        }
        for s in srcs.iter_mut() {
            s.advance_clock(i64::MAX / 8);
        }
        streams.iter_mut().for_each(|v| v.clear());
        let total = per_src * n_src;
        let mut seqs: Vec<Vec<(i64, (usize, usize))>> = Vec::new();
        for r in rdrs.iter_mut() {
            let mut seq = Vec::with_capacity(total);
            let mut backoff = Backoff::active();
            while seq.len() < total {
                match r.get() {
                    Some(t) => {
                        seq.push((t.ts, t.payload));
                        backoff.reset();
                    }
                    None => backoff.snooze(),
                }
            }
            seqs.push(seq);
        }
        // identical sequence for all readers, sorted, exactly-once
        for s in &seqs[1..] {
            assert_eq!(s, &seqs[0], "readers diverged");
        }
        assert!(seqs[0].windows(2).all(|w| w[0].0 <= w[1].0), "ts order violated");
        let mut ids: Vec<(usize, usize)> = seqs[0].iter().map(|&(_, p)| p).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "duplicate or lost tuples");
    });
}

#[test]
fn prop_esg_membership_ops_preserve_order() {
    check("esg elastic membership", 15, |tc| {
        let (g, mut srcs, mut rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
            EsgConfig { max_sources: 3, max_readers: 3, capacity: 1 << 14, source_queue: 4096 },
            2,
            1,
        );
        let n = tc.rng.range(50, 300);
        let mut ts = 0i64;
        let mut seen = Vec::new();
        let mut seq = 0u64;
        let add_reader_at = tc.rng.range(10, n);
        let remove_source_at = tc.rng.range(10, n);
        for i in 0..n {
            ts += tc.rng.gen_range(3) as i64;
            let s = tc.rng.range(0, 2);
            if g.source_active(s) {
                srcs[s].add(Tuple::data(ts, seq));
                seq += 1;
            }
            if i == add_reader_at {
                assert!(g.add_readers(&[1], 0));
            }
            if i == remove_source_at {
                g.remove_sources(&[1]);
            }
            while let Some(t) = rdrs[0].get() {
                seen.push(t.ts);
            }
        }
        srcs[0].advance_clock(i64::MAX / 8);
        while let Some(t) = rdrs[0].get() {
            seen.push(t.ts);
        }
        assert!(seen.windows(2).all(|w| w[0] <= w[1]), "order violated across membership ops");
        // the added reader sees a sorted suffix too
        let mut r1 = Vec::new();
        while let Some(t) = rdrs[1].get() {
            r1.push(t.ts);
        }
        assert!(r1.windows(2).all(|w| w[0] <= w[1]));
    });
}

// --- batched data plane ≡ per-tuple data plane ------------------------

/// One step of the scripted gate workload. Timestamps are globally
/// unique and strictly increasing across the script, so the merged log
/// order is fully determined and the per-tuple and batched executions
/// must produce *identical* per-reader sequences.
#[derive(Clone, Debug)]
enum GateOp {
    Add { src: usize, ts: i64, seq: u64 },
    Drain { max: usize },
    AddSource { src: usize, floor: i64 },
    RemoveSource { src: usize },
    AddReader,
    RemoveReader,
}

fn drain_gate_readers(
    rdrs: &mut [ReaderHandle<Tuple<u64>>],
    active: &[bool; 2],
    seqs: &mut [Vec<(i64, u64)>; 2],
    batched: bool,
    max: usize,
) {
    for i in 0..2 {
        if !active[i] {
            continue;
        }
        if batched {
            let mut buf: Vec<Tuple<u64>> = Vec::new();
            while rdrs[i].get_batch(&mut buf, max) > 0 {
                for t in buf.drain(..) {
                    seqs[i].push((t.ts, t.payload));
                }
            }
        } else {
            while let Some(t) = rdrs[i].get() {
                seqs[i].push((t.ts, t.payload));
            }
        }
    }
}

/// Execute the script on a fresh gate. `batched: false` uses
/// `add`/`get`, `batched: true` uses `add_batch` (runs buffered per
/// source) and `get_batch`.
fn run_gate_script(script: &[GateOp], batched: bool) -> [Vec<(i64, u64)>; 2] {
    let (g, mut srcs, mut rdrs): (Esg<Tuple<u64>>, _, _) = Esg::new(
        EsgConfig { max_sources: 4, max_readers: 2, capacity: 1 << 14, source_queue: 4096 },
        2,
        1,
    );
    let mut seqs: [Vec<(i64, u64)>; 2] = [Vec::new(), Vec::new()];
    let mut reader_active = [true, false];
    let mut pending: Vec<Vec<Tuple<u64>>> = (0..4).map(|_| Vec::new()).collect();
    for op in script {
        match op {
            GateOp::Add { src, ts, seq } => {
                let t = Tuple::data(*ts, *seq);
                if batched {
                    pending[*src].push(t);
                    if pending[*src].len() >= 9 {
                        srcs[*src].add_batch(&mut pending[*src]);
                    }
                } else {
                    srcs[*src].add(t);
                }
            }
            GateOp::Drain { max } => {
                if batched {
                    for (s, buf) in pending.iter_mut().enumerate() {
                        if !buf.is_empty() {
                            srcs[s].add_batch(buf);
                        }
                    }
                }
                drain_gate_readers(&mut rdrs, &reader_active, &mut seqs, batched, *max);
            }
            GateOp::AddSource { src, floor } => {
                assert!(g.add_sources(&[*src], *floor));
            }
            GateOp::RemoveSource { src } => {
                if batched && !pending[*src].is_empty() {
                    srcs[*src].add_batch(&mut pending[*src]);
                }
                assert!(g.remove_sources(&[*src]));
            }
            GateOp::AddReader => {
                // the script drains fully right before, so reader 0's
                // cursor (and hence the seed position) is identical in
                // both executions
                assert!(g.add_readers(&[1], 0));
                reader_active[1] = true;
            }
            GateOp::RemoveReader => {
                assert!(g.remove_readers(&[1]));
                reader_active[1] = false;
            }
        }
    }
    for (s, buf) in pending.iter_mut().enumerate() {
        if batched && !buf.is_empty() {
            srcs[s].add_batch(buf);
        }
    }
    for s in 0..4 {
        if g.source_active(s) {
            srcs[s].advance_clock(i64::MAX / 8);
        }
    }
    drain_gate_readers(&mut rdrs, &reader_active, &mut seqs, batched, 33);
    seqs
}

#[test]
fn prop_batched_path_matches_per_tuple_path() {
    check("batched ≡ per-tuple", 25, |tc| {
        // script generation: 2 active sources (0,1), pool 2-3; reader 1
        // joins (and may leave) mid-run; ts strictly increasing ⇒ unique
        let n_ops = tc.rng.range(100, 600);
        let mut script = Vec::with_capacity(n_ops + 8);
        let mut ts = 0i64;
        let mut seq = 0u64;
        let mut active: Vec<usize> = vec![0, 1];
        let mut next_pool = 2usize;
        let mut reader1_state = 0u8; // 0 = never added, 1 = active, 2 = removed
        for _ in 0..n_ops {
            let r = tc.rng.gen_range(100);
            if r < 70 {
                let s = active[tc.rng.range(0, active.len())];
                ts += 1 + tc.rng.gen_range(3) as i64;
                script.push(GateOp::Add { src: s, ts, seq });
                seq += 1;
            } else if r < 82 {
                script.push(GateOp::Drain { max: tc.rng.range(1, 64) });
            } else if r < 87 && active.len() > 1 {
                let s = active.remove(tc.rng.range(0, active.len()));
                script.push(GateOp::Drain { max: 8 });
                script.push(GateOp::RemoveSource { src: s });
            } else if r < 92 && next_pool < 4 {
                script.push(GateOp::AddSource { src: next_pool, floor: ts });
                active.push(next_pool);
                next_pool += 1;
            } else if r < 96 && reader1_state == 0 {
                script.push(GateOp::Drain { max: 16 });
                script.push(GateOp::AddReader);
                reader1_state = 1;
            } else if reader1_state == 1 {
                script.push(GateOp::Drain { max: 16 });
                script.push(GateOp::RemoveReader);
                reader1_state = 2;
            }
        }
        let per_tuple = run_gate_script(&script, false);
        let batched = run_gate_script(&script, true);
        for i in 0..2 {
            assert_eq!(
                per_tuple[i], batched[i],
                "seed {:#x}: reader {i} diverged between per-tuple and batched",
                tc.seed
            );
        }
        // Definition 6 on the shared prefix: sorted, exactly-once
        assert!(per_tuple[0].windows(2).all(|w| w[0].0 < w[1].0), "ts order/uniqueness violated");
        let mut ids: Vec<u64> = per_tuple[0].iter().map(|&(_, p)| p).collect();
        ids.dedup();
        assert_eq!(ids.len(), per_tuple[0].len(), "duplicate delivery");
    });
}

#[test]
fn prop_batched_concurrent_exactly_once_same_order() {
    check("batched concurrent delivery", 4, |tc| {
        let n = 15_000u64; // per source
        let (_g, srcs, rdrs) = scale_gate::<Tuple<u64>>(2, 2, 1 << 15);
        let run_seed = tc.seed;
        let producers: Vec<_> = srcs
            .into_iter()
            .take(2)
            .map(|mut s| {
                std::thread::spawn(move || {
                    let sid = s.id() as u64;
                    let mut rng = stretch::util::Rng::new(run_seed ^ (sid + 1));
                    let mut run: Vec<Tuple<u64>> = Vec::new();
                    let mut i = 0u64;
                    while i < n {
                        let len = 1 + rng.gen_range(40) as u64;
                        for _ in 0..len.min(n - i) {
                            // globally unique, per-source sorted ts
                            let ts = (2 * i + sid) as i64;
                            run.push(Tuple::data(ts, ts as u64));
                            i += 1;
                        }
                        s.add_batch(&mut run);
                    }
                    s.advance_clock(i64::MAX / 8);
                })
            })
            .collect();
        let readers: Vec<_> = rdrs
            .into_iter()
            .take(2)
            .map(|mut r| {
                std::thread::spawn(move || {
                    let total = 2 * n as usize;
                    let mut got: Vec<u64> = Vec::with_capacity(total);
                    let mut buf: Vec<Tuple<u64>> = Vec::new();
                    let mut backoff = Backoff::active();
                    while got.len() < total {
                        if r.get_batch(&mut buf, 57) == 0 {
                            backoff.snooze();
                            continue;
                        }
                        backoff.reset();
                        for t in buf.drain(..) {
                            got.push(t.payload);
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let expect: Vec<u64> = (0..2 * n).collect();
        for h in readers {
            let got = h.join().unwrap();
            assert_eq!(got, expect, "seed {:#x}: batched delivery diverged", tc.seed);
        }
    });
}

// --- randomized elastic ScaleJoin vs brute force ----------------------

struct Band;
impl JoinPredicate for Band {
    type L = (i32, f32);
    type R = (i32, f32);
    type Out = (i32, i32);
    fn matches(&self, l: &(i32, f32), r: &(i32, f32)) -> bool {
        (l.0 - r.0).abs() <= 10 && (l.1 - r.1).abs() <= 10.0
    }
    fn combine(&self, l: &(i32, f32), r: &(i32, f32)) -> (i32, i32) {
        (l.0, r.0)
    }
}
type SjIn = Either<(i32, f32), (i32, f32)>;

#[test]
fn prop_random_reconfigs_preserve_join_semantics() {
    check("elastic scalejoin", 6, |tc| {
        let n = tc.rng.range(400, 1200);
        let ws = tc.rng.range(20, 120) as i64;
        let max = 4usize;
        // workload
        let mut ts = 0i64;
        let tuples: Vec<Tuple<SjIn>> = (0..n)
            .map(|_| {
                ts += tc.rng.gen_range(2) as i64;
                let v = (tc.rng.gen_range(30) as i32, tc.rng.gen_range(30) as f32);
                if tc.rng.chance(0.5) {
                    Tuple::data_on(ts, 0, Either::L(v))
                } else {
                    Tuple::data_on(ts, 1, Either::R(v))
                }
            })
            .collect();
        // oracle
        let pred = Band;
        let mut oracle = Vec::new();
        for i in 0..tuples.len() {
            for j in 0..i {
                let (a, b) = (&tuples[i], &tuples[j]);
                if (a.ts - b.ts).abs() >= ws {
                    continue;
                }
                match (&a.payload, &b.payload) {
                    (Either::L(l), Either::R(r)) | (Either::R(r), Either::L(l)) => {
                        if pred.matches(l, r) {
                            oracle.push(pred.combine(l, r));
                        }
                    }
                    _ => {}
                }
            }
        }
        oracle.sort();
        // random reconfiguration plan: 0-3 switches to random subsets
        let n_rc = tc.rng.range(0, 4);
        let mut rc_points: Vec<usize> = (0..n_rc).map(|_| tc.rng.range(50, n - 20)).collect();
        rc_points.sort_unstable();
        rc_points.dedup();
        let rcs: Vec<(usize, Vec<usize>)> = rc_points
            .into_iter()
            .map(|at| {
                let k = tc.rng.range(1, max + 1);
                let mut ids: Vec<usize> = (0..max).collect();
                tc.rng.shuffle(&mut ids);
                ids.truncate(k);
                ids.sort_unstable();
                (at, ids)
            })
            .collect();
        // run
        let def = scalejoin_op("prop-sj", ws, Band, 32);
        let initial = tc.rng.range(1, max + 1);
        let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
            def,
            VsnOptions { initial, max, upstreams: 1, ..Default::default() },
        );
        let control = engine.control.clone();
        let mut ing = ingress.remove(0);
        let feed = tuples.clone();
        let feeder = std::thread::spawn(move || {
            let mut next = 0usize;
            for (i, t) in feed.into_iter().enumerate() {
                if next < rcs.len() && rcs[next].0 == i {
                    let set = rcs[next].1.clone();
                    control.reconfigure(set.clone(), Mapper::over(set));
                    next += 1;
                }
                ing.add(t);
            }
            ing.heartbeat(10_000_000);
        });
        let mut got = Vec::new();
        let mut reader = readers.remove(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while got.len() < oracle.len() && std::time::Instant::now() < deadline {
            match reader.get() {
                Some(t) if t.kind.is_data() => got.push(t.payload),
                Some(_) => {}
                None => std::thread::sleep(Duration::from_micros(100)),
            }
        }
        feeder.join().unwrap();
        engine.shutdown();
        got.sort();
        assert_eq!(got, oracle, "seed {:#x}: match set diverged", tc.seed);
    });
}
