//! Integration: the multi-stage pipeline layer (engine/pipeline.rs).
//!
//! A deterministic two-stage wordcount — tokenize Map → windowed count
//! Aggregate — chained through ONE shared gate (stage 1's ESG_out ≡
//! stage 2's ESG_in), checked for exact output equivalence against a
//! single-threaded brute-force reference while EACH stage is
//! independently reconfigured mid-run (Theorem 3 per stage, no state
//! transfer anywhere).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stretch::config::{Config, FaultsConfig};
use stretch::engine::dag::DagBuilder;
use stretch::engine::pipeline::{Pipeline, PipelineBuilder};
use stretch::engine::{JobSpec, VsnOptions};
use stretch::harness::{
    drive, FaultPlan, FaultPolicy, Job, JobPolicy, LaunchConfig, RecoveryKind, RecoveryLog,
    ReplaySource, SupervisorConfig, SupervisorPolicy,
};
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::workloads::nyse::{
    hedge_diamond_oracle, hedge_join_op, left_leg_op, right_leg_op, trade_filter_op, HedgeOut,
    NyseConfig, Trade, TradeStream,
};
use stretch::workloads::rates::RateSchedule;
use stretch::workloads::registry::{into_job_tuple, JobPayload};
use stretch::workloads::tweets::{
    tokenize_op, word_count_stage_op, wordcount_keys, Tweet, TweetGen, TweetGenConfig,
};

/// Brute-force single-threaded reference: (window_right, word) → count
/// over windows fully expired before `horizon`.
fn reference_counts(
    tuples: &[Tuple<Tweet>],
    spec: WindowSpec,
    horizon: i64,
) -> BTreeMap<(i64, Key), u64> {
    let mut m = BTreeMap::new();
    let mut keys = Vec::new();
    for t in tuples {
        keys.clear();
        wordcount_keys(t, &mut keys); // == tokenize: distinct words
        let mut l = spec.earliest_win_l(t.ts);
        while l <= spec.latest_win_l(t.ts) {
            if l + spec.size <= horizon {
                for &k in &keys {
                    *m.entry((l + spec.size, k)).or_default() += 1;
                }
            }
            l += spec.advance;
        }
    }
    m
}

fn corpus(n: usize) -> Vec<Tuple<Tweet>> {
    TweetGen::new(TweetGenConfig {
        vocab: 400,
        hashtag_vocab: 20,
        seed: 0xDA6,
        mean_gap_ms: 2.0,
        ..Default::default()
    })
    .take(n)
}

#[test]
fn two_stage_pipeline_matches_reference_under_per_stage_reconfigs() {
    let spec = WindowSpec::new(500, 500);
    let n = 4_000usize;
    let tuples = corpus(n);
    let horizon = tuples.last().unwrap().ts + 20_000;
    let oracle = reference_counts(&tuples, spec, horizon);
    assert!(!oracle.is_empty(), "degenerate corpus");

    let mut pipeline = PipelineBuilder::new(
        tokenize_op(64),
        VsnOptions { initial: 1, max: 3, gate_capacity: 8192, ..Default::default() },
    )
    .stage(
        word_count_stage_op(spec),
        VsnOptions { initial: 2, max: 4, gate_capacity: 8192, ..Default::default() },
    )
    .build();
    assert_eq!(pipeline.depth(), 2);

    // feeder thread: the ingress wrapper forwards stage 0's control
    // tuples in-band, so reconfigure calls may race freely with it
    let progress = Arc::new(AtomicUsize::new(0));
    let feed = tuples.clone();
    let mut ing = pipeline.ingress.remove(0);
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(t).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    // collect while reconfiguring each stage once, mid-run
    let mut reader = pipeline.egress.remove(0);
    let mut got: BTreeMap<(i64, Key), u64> = BTreeMap::new();
    let want_entries = oracle.len();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut did_stage0 = false;
    let mut did_stage1 = false;
    while got.len() < want_entries && std::time::Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        if !did_stage0 && p > n / 3 {
            pipeline.reconfigure_stage(0, vec![0, 1, 2]); // tokenize: 1 → 3
            did_stage0 = true;
        }
        if !did_stage1 && p > 2 * n / 3 {
            pipeline.reconfigure_stage(1, vec![0, 1, 2, 3]); // count: 2 → 4
            did_stage1 = true;
        }
        match reader.get() {
            Some(t) if t.kind.is_data() => {
                got.insert((t.ts, t.payload.0), t.payload.1);
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    feeder.join().unwrap();
    assert!(did_stage0 && did_stage1, "reconfig triggers never fired");

    // both reconfigurations completed, independently, on their own stage
    let t0 = std::time::Instant::now();
    while (pipeline.stages[0].completion_times().is_empty()
        || pipeline.stages[1].completion_times().is_empty())
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(pipeline.stages[0].completion_times().len(), 1, "stage 0 reconfig incomplete");
    assert_eq!(pipeline.stages[1].completion_times().len(), 1, "stage 1 reconfig incomplete");
    assert_eq!(pipeline.stages[0].active_instances(), vec![0, 1, 2]);
    assert_eq!(pipeline.stages[1].active_instances(), vec![0, 1, 2, 3]);
    pipeline.shutdown();

    assert_eq!(got, oracle, "pipeline output diverged from the sequential reference");
}

#[test]
fn pipeline_shrink_preserves_equivalence() {
    // decommission mid-run on both stages (3→1 and 2→1)
    let spec = WindowSpec::new(400, 400);
    let n = 2_500usize;
    let tuples = corpus(n);
    let horizon = tuples.last().unwrap().ts + 20_000;
    let oracle = reference_counts(&tuples, spec, horizon);

    let mut pipeline = PipelineBuilder::new(
        tokenize_op(64),
        VsnOptions { initial: 3, max: 3, gate_capacity: 8192, ..Default::default() },
    )
    .stage(
        word_count_stage_op(spec),
        VsnOptions { initial: 2, max: 2, gate_capacity: 8192, ..Default::default() },
    )
    .build();

    let progress = Arc::new(AtomicUsize::new(0));
    let feed = tuples.clone();
    let mut ing = pipeline.ingress.remove(0);
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(t).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let mut reader = pipeline.egress.remove(0);
    let mut got: BTreeMap<(i64, Key), u64> = BTreeMap::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut did = false;
    while got.len() < oracle.len() && std::time::Instant::now() < deadline {
        if !did && progress.load(Ordering::Relaxed) > n / 2 {
            pipeline.reconfigure_stage(0, vec![1]);
            pipeline.reconfigure_stage(1, vec![0]);
            did = true;
        }
        match reader.get() {
            Some(t) if t.kind.is_data() => {
                got.insert((t.ts, t.payload.0), t.payload.1);
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    feeder.join().unwrap();
    pipeline.shutdown();
    assert_eq!(got, oracle, "shrink reconfigs must not lose or double-count windows");
}

type Match = (u16, i32, u16, i32);

fn diamond_corpus(ws_ms: i64, n: usize) -> (Vec<Tuple<Trade>>, i64, Vec<Match>) {
    let cfg = NyseConfig { symbols: 8, ..Default::default() };
    let mut stream = TradeStream::new(&cfg, 1_000.0);
    let trades: Vec<Tuple<Trade>> = (0..n).map(|_| stream.next()).collect();
    let horizon = trades.last().unwrap().ts + ws_ms + 10_000;
    let mut oracle: Vec<Match> = hedge_diamond_oracle(&trades, ws_ms)
        .into_iter()
        .map(|h| (h.l_id, h.l_price, h.r_id, h.r_price))
        .collect();
    oracle.sort_unstable();
    assert!(!oracle.is_empty(), "degenerate corpus: no hedge matches");
    (trades, horizon, oracle)
}

/// Drive any 4-stage diamond (hand-built or config-built) with the same
/// trade corpus while reconfiguring EVERY stage mid-run — grow the
/// source, grow the left leg, SHRINK the right leg, grow the join —
/// then return the sorted match multiset plus the final instance sets.
fn drive_diamond<In, Out>(
    mut pipeline: Pipeline<In, Out>,
    trades: &[Tuple<Trade>],
    horizon: i64,
    expected: usize,
    wrap: fn(Tuple<Trade>) -> Tuple<In>,
    extract: fn(&Out) -> Match,
) -> (Vec<Match>, Vec<Vec<usize>>)
where
    In: Clone + Send + Sync + Default + 'static,
    Out: Clone + Send + Sync + Default + 'static,
{
    assert_eq!(pipeline.depth(), 4);
    assert_eq!(pipeline.ingress.len(), 1);
    assert_eq!(pipeline.egress.len(), 1);
    let n = trades.len();

    let progress = Arc::new(AtomicUsize::new(0));
    let feed = trades.to_vec();
    let mut ing = pipeline.ingress.remove(0);
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(wrap(t)).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let mut reader = pipeline.egress.remove(0);
    let mut got: Vec<Match> = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let mut fired = [false; 4];
    let plan: [(usize, Vec<usize>); 4] =
        [(0, vec![0, 1]), (1, vec![0, 1]), (2, vec![1]), (3, vec![0, 1, 2])];
    let mut buf: Vec<Tuple<Out>> = Vec::new();
    while got.len() < expected && std::time::Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        for (i, (stage, set)) in plan.iter().enumerate() {
            if !fired[i] && p > (i + 1) * n / 5 {
                pipeline.reconfigure_stage(*stage, set.clone());
                fired[i] = true;
            }
        }
        buf.clear();
        if reader.get_batch(&mut buf, 256) == 0 {
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        for t in &buf {
            if t.kind.is_data() {
                got.push(extract(&t.payload));
            }
        }
    }
    feeder.join().unwrap();
    assert!(fired.iter().all(|&f| f), "not every reconfig trigger fired: {fired:?}");

    // every stage completed its reconfiguration independently
    let t0 = std::time::Instant::now();
    while pipeline.stages.iter().any(|s| s.completion_times().is_empty())
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    for (k, stage) in pipeline.stages.iter().enumerate() {
        assert_eq!(stage.completion_times().len(), 1, "stage {k} ({}) reconfig lost", stage.name());
    }
    let finals: Vec<Vec<usize>> = pipeline.stages.iter().map(|s| s.active_instances()).collect();
    pipeline.shutdown();
    got.sort_unstable();
    (got, finals)
}

fn extract_hedge(h: &HedgeOut) -> Match {
    (h.l_id, h.l_price, h.r_id, h.r_price)
}

fn extract_job(p: &JobPayload) -> Match {
    match p {
        JobPayload::Hedge(h) => (h.l_id, h.l_price, h.r_id, h.r_price),
        other => panic!("diamond sink must emit hedge matches, got {other:?}"),
    }
}

fn hand_built_diamond(ws_ms: i64) -> Pipeline<Trade, HedgeOut> {
    let mut b = DagBuilder::<Trade>::new();
    let s = b.source(
        trade_filter_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 8192, ..Default::default() },
    );
    let l = b.node(
        left_leg_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 8192, ..Default::default() },
        &[s],
    );
    let r = b.node(
        right_leg_op(64),
        VsnOptions { initial: 2, max: 2, gate_capacity: 8192, ..Default::default() },
        &[s],
    );
    let j = b.node(
        hedge_join_op(ws_ms, 32),
        VsnOptions { initial: 1, max: 3, gate_capacity: 8192, ..Default::default() },
        &[l, r],
    );
    b.build(&[j]).expect("diamond is a valid DAG")
}

/// The tentpole's end-to-end proof: a DIAMOND topology
/// (filter → L-leg ∥ R-leg → hedge join → sink) built on shared gates —
/// fan-out as two reader groups on one ESG_out, fan-in as two
/// source-slot groups on the join's ESG_in — producing EXACTLY the
/// sequential reference's match multiset while every one of the four
/// stages reconfigures mid-run through its own per-edge control slot.
#[test]
fn diamond_dag_matches_reference_while_every_stage_reconfigures() {
    let ws_ms = 800i64;
    let (trades, horizon, oracle) = diamond_corpus(ws_ms, 2_500);
    let pipeline = hand_built_diamond(ws_ms);
    let (got, finals) =
        drive_diamond(pipeline, &trades, horizon, oracle.len(), |t| t, extract_hedge);
    assert_eq!(
        finals,
        vec![vec![0, 1], vec![0, 1], vec![1], vec![0, 1, 2]],
        "final instance sets diverged from the reconfig plan"
    );
    assert_eq!(got.len(), oracle.len(), "match count diverged from the sequential reference");
    assert_eq!(got, oracle, "diamond DAG output diverged from the sequential reference");
}

/// The live-runtime-API proof: the SAME diamond, driven through
/// [`Job::launch`]'s [`stretch::harness::JobHandle`] instead of a
/// hand-rolled feeder/reader pair — the corpus replays through a
/// [`ReplaySource`] (exactly-once, end-of-stream on exhaustion), all four
/// stages are scaled by scripted `scale_to` calls on the handle, and
/// every [`stretch::harness::ReconfigTicket`] must resolve with a
/// measured reconfiguration latency. The output multiset must equal both
/// the sequential oracle and the manually driven run.
#[test]
fn handle_scripted_diamond_matches_reference_and_resolves_tickets() {
    let ws_ms = 800i64;
    let (trades, horizon, oracle) = diamond_corpus(ws_ms, 2_500);
    let (hand, hand_finals) = drive_diamond(
        hand_built_diamond(ws_ms),
        &trades,
        horizon,
        oracle.len(),
        |t| t,
        extract_hedge,
    );

    let n = trades.len();
    // ~2k tuples per wall second: the corpus spans >1 s of wall time, so
    // the last feed-progress trigger (4n/5) lands hundreds of ms before
    // end-of-stream — a scale issued after the EOS heartbeat could never
    // complete and would flake the ticket asserts below
    let handle = Job::new(hand_built_diamond(ws_ms), ReplaySource::new(trades.clone()))
        .with_config(LaunchConfig {
            name: "diamond-handle".into(),
            schedule: RateSchedule::constant(60, 1_000.0),
            time_scale: 2.0,
            flush_slack_ms: ws_ms + 10_000,
            drain: Duration::from_millis(300),
            capture_egress: true,
            ..Default::default()
        })
        .launch()
        .expect("diamond launches");

    // same plan as drive_diamond: grow source, grow left, SHRINK right,
    // grow join — issued through the live handle at feed-progress marks
    let plan: [(usize, Vec<usize>); 4] =
        [(0, vec![0, 1]), (1, vec![0, 1]), (2, vec![1]), (3, vec![0, 1, 2])];
    let mut fired = [false; 4];
    let mut tickets = Vec::new();
    let mut got: Vec<Match> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    while Instant::now() < deadline {
        let m = handle.sample();
        for (i, (stage, set)) in plan.iter().enumerate() {
            if !fired[i] && m.fed > ((i + 1) * n / 5) as u64 {
                tickets.push(handle.scale_to(*stage, set.clone()));
                fired[i] = true;
            }
        }
        for t in handle.take_egress() {
            if t.kind.is_data() {
                got.push(extract_hedge(&t.payload));
            }
        }
        if got.len() >= oracle.len() && fired.iter().all(|&f| f) {
            break;
        }
        if handle.quiesced() {
            break; // feed done and egress quiet: no more output is coming
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(fired.iter().all(|&f| f), "not every scripted scale fired: {fired:?}");

    // the <40 ms claim as an observable: every ticket resolves with a
    // measured latency (the end-of-stream heartbeat flushes stragglers)
    for t in &tickets {
        let ms = t.wait(Duration::from_secs(30));
        assert!(ms.is_some(), "ticket for stage {} never resolved: {t:?}", t.stage());
        assert!(ms.unwrap() >= 0.0);
    }
    // ticket resolution implies the epochs are installed; give the
    // published live view (refreshed per runtime tick) a moment to match
    let want_finals = hand_finals.clone();
    let mut finals: Vec<Vec<usize>> = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(2) {
        finals = handle.sample().stages.iter().map(|s| s.active.clone()).collect();
        if finals == want_finals {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(finals, want_finals, "final instance sets diverged from the scripted plan");

    handle.await_quiesce();
    for t in handle.take_egress() {
        if t.kind.is_data() {
            got.push(extract_hedge(&t.payload));
        }
    }
    let outcome = handle.shutdown();
    assert_eq!(outcome.tickets.len(), 4, "handle must log every scripted reconfig");
    assert!(outcome.tickets.iter().all(|t| t.latency_ms().is_some()));
    assert_eq!(outcome.result.ingress_dropped, 0, "replay must not lose tuples");

    got.sort_unstable();
    assert_eq!(got, oracle, "handle-scripted diamond diverged from the sequential reference");
    assert_eq!(got, hand, "handle-scripted diamond diverged from the manually driven run");
}

/// The diamond with enough slack for chaos: every stage has survivors
/// (`initial = 2`) and a spare slot (`max = 3`) so a killed worker can
/// be evicted and the stage re-grown onto a FRESH id (dead slots are
/// terminal) — the same pools `examples/configs/diamond_faults.conf`
/// declares.
fn chaos_diamond(ws_ms: i64) -> Pipeline<Trade, HedgeOut> {
    let opts =
        |initial| VsnOptions { initial, max: 3, gate_capacity: 8192, ..Default::default() };
    let mut b = DagBuilder::<Trade>::new();
    let s = b.source(trade_filter_op(64), opts(2));
    let l = b.node(left_leg_op(64), opts(2), &[s]);
    let r = b.node(right_leg_op(64), opts(2), &[s]);
    let j = b.node(hedge_join_op(ws_ms, 32), opts(2), &[l, r]);
    b.build(&[j]).expect("diamond is a valid DAG")
}

/// The robustness tentpole's end-to-end proof: the diamond under the
/// checked-in chaos script (`examples/configs/diamond_faults.conf`) —
/// one worker KILLED on each stateless stage, one join worker STALLED
/// past the detector window — driven by [`FaultPolicy`] +
/// [`SupervisorPolicy`] through the live handle. Recovery IS
/// reconfiguration: each dead worker's zombie replays its unprocessed
/// share through the surviving epoch, the supervisor re-grows the stage
/// on fresh slots, and the egress multiset must STILL equal the
/// sequential oracle exactly. Every [`stretch::harness::RecoveryTicket`]
/// must resolve healed with a measured MTTR and the job must not be
/// marked degraded.
#[test]
fn chaos_diamond_heals_every_fault_and_matches_reference() {
    let ws_ms = 800i64;
    let (trades, _horizon, oracle) = diamond_corpus(ws_ms, 2_500);

    // the fault script comes from the checked-in config — the test and
    // the `stretch run` smoke exercise the same scenario
    let conf = Config::load("examples/configs/diamond_faults.conf")
        .expect("examples/configs/diamond_faults.conf loads");
    let faults = FaultsConfig::from_config(&conf);
    assert!(faults.enabled && faults.supervise, "conf must opt into supervision");
    let steps = conf.str_list("faults.steps").expect("conf scripts its faults");
    let plan = FaultPlan::parse(&steps, &[("filter", 3), ("left", 3), ("right", 3), ("join", 3)])
        .expect("conf fault script parses against the diamond");

    // replay slowly enough that the last fault (event second 3) heals
    // well before end-of-stream: 2 500 tuples at 1 000 t/s wall ≈ 2.5 s
    let handle = Job::new(chaos_diamond(ws_ms), ReplaySource::new(trades.clone()))
        .with_config(LaunchConfig {
            name: "diamond-chaos".into(),
            schedule: RateSchedule::constant(60, 500.0),
            time_scale: 2.0,
            flush_slack_ms: ws_ms + 10_000,
            drain: Duration::from_millis(300),
            capture_egress: true,
            stall_after_ms: faults.stall_after_ms,
            ..Default::default()
        })
        .launch()
        .expect("chaos diamond launches");

    let log = RecoveryLog::new();
    let mut policies: Vec<Box<dyn JobPolicy>> = vec![
        Box::new(FaultPolicy::new(plan)),
        Box::new(SupervisorPolicy::new(SupervisorConfig::default(), log.clone())),
    ];
    drive(&handle, &mut policies);

    // quiesced: the healed membership is in the published live view
    let finals: Vec<Vec<usize>> =
        handle.sample().stages.iter().map(|s| s.active.clone()).collect();
    assert_eq!(
        finals,
        vec![vec![1, 2], vec![0, 2], vec![1, 2], vec![0, 1]],
        "each killed stage must be re-grown onto fresh slots, the stalled join untouched"
    );

    let mut got: Vec<Match> = handle
        .take_egress()
        .iter()
        .filter(|t| t.kind.is_data())
        .map(|t| extract_hedge(&t.payload))
        .collect();
    let outcome = handle.shutdown();
    log.close_unresolved();

    assert!(!log.degraded(), "every fault is recoverable — no escalation to degraded");
    let recoveries = log.tickets();
    let crashes =
        recoveries.iter().filter(|t| t.kind() == RecoveryKind::Crash).count();
    let stalls = recoveries.iter().filter(|t| t.kind() == RecoveryKind::Stall).count();
    assert_eq!(crashes, 3, "one crash ticket per killed worker: {recoveries:?}");
    assert!(stalls >= 1, "the stalled join worker must be detected: {recoveries:?}");
    for t in &recoveries {
        let ms = t.mttr_ms();
        assert!(ms.is_some(), "recovery never healed: {t:?}");
        assert!(ms.unwrap().is_finite() && ms.unwrap() >= 0.0, "bogus MTTR: {t:?}");
    }
    assert!(!outcome.tickets.is_empty(), "healing must flow through reconfig tickets");
    assert_eq!(outcome.result.ingress_dropped, 0, "replay must not lose tuples");

    got.sort_unstable();
    assert_eq!(got.len(), oracle.len(), "match count diverged under chaos");
    assert_eq!(got, oracle, "chaos diamond diverged from the sequential reference");
}

/// The exact topology of [`hand_built_diamond`] as a `[topology]` config
/// (same parallelism, gate capacities and join parameters) — the
/// declarative layer's equivalence fixture.
const DIAMOND_JOB: &str = r#"
name = "diamond-equivalence"
[topology]
stages = ["filter", "left", "right", "join"]
edges = ["filter -> left", "filter -> right", "left -> join", "right -> join"]
[stage.filter]
operator = "trade-filter"
initial = 1
max = 2
gate_capacity = 8192
[stage.left]
operator = "left-leg"
initial = 1
max = 2
gate_capacity = 8192
[stage.right]
operator = "right-leg"
initial = 2
max = 2
gate_capacity = 8192
[stage.join]
operator = "hedge-join"
ws_ms = 800
keys = 32
initial = 1
max = 3
gate_capacity = 8192
"#;

/// The JobSpec layer's acceptance proof: a diamond built FROM CONFIG
/// produces output exactly equivalent to the hand-built `DagBuilder`
/// diamond — same corpus, same mid-run reconfiguration of every stage,
/// identical match multisets (and both equal the sequential reference).
#[test]
fn config_built_diamond_matches_hand_built_while_every_stage_reconfigures() {
    let ws_ms = 800i64;
    let (trades, horizon, oracle) = diamond_corpus(ws_ms, 2_500);

    let (hand, hand_finals) = drive_diamond(
        hand_built_diamond(ws_ms),
        &trades,
        horizon,
        oracle.len(),
        |t| t,
        extract_hedge,
    );

    let spec = JobSpec::from_config(&Config::parse(DIAMOND_JOB).unwrap())
        .expect("diamond job config is valid");
    assert_eq!(spec.source_kind, stretch::workloads::PayloadKind::Trade);
    let built = spec.build().expect("diamond job builds");
    assert_eq!(built.stage_names, ["filter", "left", "right", "join"]);
    let (conf, conf_finals) = drive_diamond(
        built.pipeline,
        &trades,
        horizon,
        oracle.len(),
        into_job_tuple::<Trade>,
        extract_job,
    );

    assert_eq!(hand, oracle, "hand-built diamond diverged from the sequential reference");
    assert_eq!(conf, hand, "config-built diamond diverged from the hand-built one");
    assert_eq!(conf_finals, hand_finals, "per-stage final instance sets diverged");
}
