//! Integration: the SN baseline and the VSN (STRETCH) engine produce the
//! same results for the same inputs — the semantic-equivalence claim of
//! Theorems 2/3 — and VSN does it without data duplication (Observation 2).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{SnEngine, SnOptions, VsnEngine, VsnOptions};
use stretch::operator::aggregate::count_per_key_op;
use stretch::operator::join::{scalejoin_op, Either, JoinPredicate};
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::util::Rng;

type WcIn = Arc<Vec<Key>>;

/// Generate a multi-key workload (each tuple carries 1-4 keys).
fn gen_multikey(seed: u64, n: usize, key_space: u64) -> Vec<Tuple<WcIn>> {
    let mut rng = Rng::new(seed);
    let mut ts = 0i64;
    (0..n)
        .map(|_| {
            ts += rng.gen_range(3) as i64;
            let k = rng.range(1, 5);
            let mut keys: Vec<Key> = (0..k).map(|_| rng.gen_range(key_space)).collect();
            keys.sort_unstable();
            keys.dedup();
            Tuple::data(ts, Arc::new(keys))
        })
        .collect()
}

/// Brute-force oracle: (window_right, key) → count.
fn count_oracle(tuples: &[Tuple<WcIn>], spec: WindowSpec, horizon: i64) -> BTreeMap<(i64, Key), u64> {
    let mut m = BTreeMap::new();
    for t in tuples {
        let mut l = spec.earliest_win_l(t.ts);
        while l <= spec.latest_win_l(t.ts) {
            if l + spec.size <= horizon {
                for &k in t.payload.iter() {
                    *m.entry((l + spec.size, k)).or_default() += 1;
                }
            }
            l += spec.advance;
        }
    }
    m
}

fn collect_vsn_counts(
    tuples: &[Tuple<WcIn>],
    spec: WindowSpec,
    m: usize,
    horizon: i64,
) -> (BTreeMap<(i64, Key), u64>, u64) {
    let def = count_per_key_op::<WcIn, _>("wc", spec, |t, keys| keys.extend_from_slice(&t.payload));
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: m, max: m + 2, upstreams: 1, ..Default::default() },
    );
    for t in tuples {
        ingress[0].add(t.clone()).unwrap();
    }
    ingress[0].heartbeat(horizon).unwrap();
    let expected = count_oracle(tuples, spec, horizon).len() as u64;
    let mut out = BTreeMap::new();
    let mut reader = readers.remove(0);
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut got = 0u64;
    while got < expected && std::time::Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => {
                out.insert((t.ts, t.payload.0), t.payload.1);
                got += 1;
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    let published = engine.esg_in.published();
    engine.shutdown();
    (out, published)
}

fn collect_sn_counts(
    tuples: &[Tuple<WcIn>],
    spec: WindowSpec,
    pi: usize,
    horizon: i64,
) -> (BTreeMap<(i64, Key), u64>, u64) {
    let def = count_per_key_op::<WcIn, _>("wc", spec, |t, keys| keys.extend_from_slice(&t.payload));
    let (mut engine, mut ingress, mut egress) = SnEngine::setup(
        def,
        SnOptions { parallelism: pi, upstreams: 1, ..Default::default() },
    );
    // batched forwardSN (the harness path): one staged flush per run
    // instead of a per-(tuple, target) push, so SN-vs-VSN comparisons
    // measure the engines, not an unbatched baseline
    let mut run: Vec<Tuple<WcIn>> = Vec::with_capacity(256);
    for t in tuples {
        run.push(t.clone());
        if run.len() >= 256 {
            ingress[0].forward_batch(&mut run);
        }
    }
    ingress[0].forward_batch(&mut run);
    ingress[0].heartbeat(horizon);
    let expected = count_oracle(tuples, spec, horizon).len() as u64;
    let mut out = BTreeMap::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while (out.len() as u64) < expected && std::time::Instant::now() < deadline {
        let drained = egress.poll_tuples(&mut |t: &Tuple<(Key, u64)>| {
            out.insert((t.ts, t.payload.0), t.payload.1);
        });
        if drained == 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let forwarded = engine.forwarded.load(std::sync::atomic::Ordering::Relaxed);
    engine.shutdown();
    (out, forwarded)
}

#[test]
fn vsn_counting_matches_oracle() {
    let spec = WindowSpec::new(50, 50);
    let tuples = gen_multikey(11, 3000, 40);
    let horizon = 1_000_000;
    let oracle = count_oracle(&tuples, spec, horizon);
    let (got, published) = collect_vsn_counts(&tuples, spec, 2, horizon);
    assert_eq!(got, oracle);
    // Observation 2 — no duplication: each input published exactly once
    // (+ the single end-of-stream heartbeat clock advance, not an entry)
    assert!(published as usize <= tuples.len() + 16, "published={published}");
}

#[test]
fn sn_counting_matches_oracle_and_duplicates() {
    let spec = WindowSpec::new(50, 50);
    let tuples = gen_multikey(12, 3000, 40);
    let horizon = 1_000_000;
    let oracle = count_oracle(&tuples, spec, horizon);
    let (got, forwarded) = collect_sn_counts(&tuples, spec, 3, horizon);
    assert_eq!(got, oracle);
    // Theorem 1: multi-key tuples are duplicated across instances
    assert!(
        forwarded as usize > tuples.len(),
        "expected duplication: forwarded={forwarded} inputs={}",
        tuples.len()
    );
}

#[test]
fn sn_and_vsn_agree() {
    let spec = WindowSpec::new(30, 90); // sliding
    let tuples = gen_multikey(13, 2000, 25);
    let horizon = 500_000;
    let (vsn, _) = collect_vsn_counts(&tuples, spec, 3, horizon);
    let (sn, _) = collect_sn_counts(&tuples, spec, 3, horizon);
    assert_eq!(vsn, sn);
}

/// The §8.3 band predicate over compact numeric payloads.
struct Band;
impl JoinPredicate for Band {
    type L = (i32, f32);
    type R = (i32, f32);
    type Out = (i32, i32);
    fn matches(&self, l: &(i32, f32), r: &(i32, f32)) -> bool {
        (l.0 - r.0).abs() <= 10 && (l.1 - r.1).abs() <= 10.0
    }
    fn combine(&self, l: &(i32, f32), r: &(i32, f32)) -> (i32, i32) {
        (l.0, r.0)
    }
}

type SjIn = Either<(i32, f32), (i32, f32)>;

fn gen_join(seed: u64, n: usize, range: u64) -> Vec<Tuple<SjIn>> {
    let mut rng = Rng::new(seed);
    let mut ts = 0i64;
    (0..n)
        .map(|_| {
            ts += rng.gen_range(2) as i64;
            let v = (rng.gen_range(range) as i32, rng.gen_range(range) as f32);
            if rng.chance(0.5) {
                Tuple::data_on(ts, 0, Either::L(v))
            } else {
                Tuple::data_on(ts, 1, Either::R(v))
            }
        })
        .collect()
}

/// Brute-force join oracle (multiset of combined payloads). A pair
/// matches iff the later tuple arrives before the earlier one slid out
/// of the WS window (strict: |Δts| < WS given WA = δ purging).
fn join_oracle(tuples: &[Tuple<SjIn>], ws: i64) -> Vec<(i32, i32)> {
    let pred = Band;
    let mut out = Vec::new();
    for i in 0..tuples.len() {
        for j in 0..i {
            let (a, b) = (&tuples[i], &tuples[j]);
            if (a.ts - b.ts).abs() >= ws {
                continue;
            }
            match (&a.payload, &b.payload) {
                (Either::L(l), Either::R(r)) | (Either::R(r), Either::L(l)) => {
                    if pred.matches(l, r) {
                        out.push(pred.combine(l, r));
                    }
                }
                _ => {}
            }
        }
    }
    out.sort();
    out
}

fn run_vsn_join(tuples: &[Tuple<SjIn>], ws: i64, m: usize, expected: usize) -> Vec<(i32, i32)> {
    let def = scalejoin_op("sj", ws, Band, 64);
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: m, max: m + 2, upstreams: 1, ..Default::default() },
    );
    // feed from a separate thread (backpressure can block the feeder)
    let feed = tuples.to_vec();
    let mut ing0 = ingress.remove(0);
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing0.add(t).unwrap();
        }
        ing0.heartbeat(10_000_000).unwrap();
    });
    let mut out = Vec::new();
    let mut reader = readers.remove(0);
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    while out.len() < expected && std::time::Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => out.push(t.payload),
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    feeder.join().unwrap();
    engine.shutdown();
    out.sort();
    out
}

#[test]
fn vsn_scalejoin_matches_bruteforce() {
    let tuples = gen_join(21, 1500, 40);
    let oracle = join_oracle(&tuples, 100);
    assert!(!oracle.is_empty(), "degenerate workload");
    let got = run_vsn_join(&tuples, 100, 1, oracle.len());
    assert_eq!(got, oracle);
}

#[test]
fn vsn_scalejoin_parallelism_invariant() {
    let tuples = gen_join(22, 1200, 30);
    let oracle = join_oracle(&tuples, 80);
    let got1 = run_vsn_join(&tuples, 80, 1, oracle.len());
    let got3 = run_vsn_join(&tuples, 80, 3, oracle.len());
    assert_eq!(got1, oracle);
    assert_eq!(got3, oracle, "Π=3 must find exactly the same matches");
}
