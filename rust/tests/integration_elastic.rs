//! Integration: elastic reconfigurations — provisioning, decommissioning,
//! load balancing — preserve `O+` semantics (Theorem 3/4) with no state
//! transfer, and complete in far under the paper's 40 ms bound.

use std::sync::Arc;
use std::time::Duration;

use stretch::engine::{InjectedFault, VsnEngine, VsnOptions};
use stretch::operator::join::{scalejoin_op, Either, JoinPredicate};
use stretch::operator::OperatorDef;
use stretch::tuple::{Mapper, Tuple};
use stretch::util::Rng;

struct Band;
impl JoinPredicate for Band {
    type L = (i32, f32);
    type R = (i32, f32);
    type Out = (i32, i32);
    fn matches(&self, l: &(i32, f32), r: &(i32, f32)) -> bool {
        (l.0 - r.0).abs() <= 10 && (l.1 - r.1).abs() <= 10.0
    }
    fn combine(&self, l: &(i32, f32), r: &(i32, f32)) -> (i32, i32) {
        (l.0, r.0)
    }
}

type SjIn = Either<(i32, f32), (i32, f32)>;

fn gen_join(seed: u64, n: usize, start_ts: i64) -> Vec<Tuple<SjIn>> {
    let mut rng = Rng::new(seed);
    let mut ts = start_ts;
    (0..n)
        .map(|_| {
            ts += rng.gen_range(2) as i64;
            let v = (rng.gen_range(30) as i32, rng.gen_range(30) as f32);
            if rng.chance(0.5) {
                Tuple::data_on(ts, 0, Either::L(v))
            } else {
                Tuple::data_on(ts, 1, Either::R(v))
            }
        })
        .collect()
}

fn join_oracle(tuples: &[Tuple<SjIn>], ws: i64) -> Vec<(i32, i32)> {
    let pred = Band;
    let mut out = Vec::new();
    for i in 0..tuples.len() {
        for j in 0..i {
            let (a, b) = (&tuples[i], &tuples[j]);
            if (a.ts - b.ts).abs() >= ws {
                continue;
            }
            match (&a.payload, &b.payload) {
                (Either::L(l), Either::R(r)) | (Either::R(r), Either::L(l)) => {
                    if pred.matches(l, r) {
                        out.push(pred.combine(l, r));
                    }
                }
                _ => {}
            }
        }
    }
    out.sort();
    out
}

/// Run a join workload with reconfigurations at given positions:
/// `(after_n_tuples, new_instance_set)`.
fn run_elastic(
    tuples: &[Tuple<SjIn>],
    ws: i64,
    initial: usize,
    max: usize,
    reconfigs: &[(usize, Vec<usize>)],
    expected: usize,
) -> (Vec<(i32, i32)>, Vec<(u64, f64)>, Vec<usize>) {
    let def = scalejoin_op("sj", ws, Band, 64);
    // Small gate: reconfiguration-time measurements include the time the
    // control tuple spends queued behind unprocessed tuples, so bound the
    // backlog the way the paper's flow control does.
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial, max, upstreams: 1, gate_capacity: 2048, ..Default::default() },
    );
    let control = engine.control.clone();
    // Feed from a separate thread: with flow control on, the feeder can
    // block on backpressure until the egress (this thread) drains.
    let feed_tuples = tuples.to_vec();
    let feed_rcs = reconfigs.to_vec();
    let feed_control = control.clone();
    let mut ing0 = ingress.remove(0);
    let feeder = std::thread::spawn(move || {
        let mut next_rc = 0usize;
        for (i, t) in feed_tuples.iter().enumerate() {
            if next_rc < feed_rcs.len() && feed_rcs[next_rc].0 == i {
                let set = feed_rcs[next_rc].1.clone();
                feed_control.reconfigure(set.clone(), Mapper::over(set));
                next_rc += 1;
            }
            ing0.add(t.clone()).unwrap();
        }
        ing0.heartbeat(10_000_000).unwrap();
    });
    let mut out = Vec::new();
    let mut reader = readers.remove(0);
    let deadline = std::time::Instant::now() + Duration::from_secs(40);
    while out.len() < expected && std::time::Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => out.push(t.payload),
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    feeder.join().unwrap();
    // give completions a moment to be recorded
    let t0 = std::time::Instant::now();
    while engine.control.completion_times().len() < reconfigs.len()
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let completions = engine.control.completion_times();
    let final_instances = engine.epoch_config().instances.as_ref().clone();
    engine.shutdown();
    out.sort();
    (out, completions, final_instances)
}

#[test]
fn provisioning_preserves_semantics() {
    let tuples = gen_join(31, 2000, 0);
    let oracle = join_oracle(&tuples, 80);
    // 1 → 3 instances midway
    let (got, completions, finals) =
        run_elastic(&tuples, 80, 1, 4, &[(1000, vec![0, 1, 2])], oracle.len());
    assert_eq!(got, oracle, "matches must survive provisioning");
    assert_eq!(completions.len(), 1, "reconfig must complete");
    assert_eq!(finals, vec![0, 1, 2]);
}

#[test]
fn decommissioning_preserves_semantics() {
    let tuples = gen_join(32, 2000, 0);
    let oracle = join_oracle(&tuples, 80);
    // 3 → 1 instances midway
    let (got, completions, finals) =
        run_elastic(&tuples, 80, 3, 4, &[(1000, vec![0])], oracle.len());
    assert_eq!(got, oracle, "matches must survive decommissioning");
    assert_eq!(completions.len(), 1);
    assert_eq!(finals, vec![0]);
}

#[test]
fn multiple_sequential_reconfigs() {
    let tuples = gen_join(33, 3000, 0);
    let oracle = join_oracle(&tuples, 60);
    let rcs = vec![
        (500, vec![0, 1]),
        (1200, vec![0, 1, 2, 3]),
        (1900, vec![2, 3]),
        (2500, vec![0, 3]),
    ];
    let (got, completions, finals) = run_elastic(&tuples, 60, 1, 4, &rcs, oracle.len());
    assert_eq!(got, oracle, "matches must survive repeated reconfiguration");
    assert_eq!(completions.len(), 4);
    assert_eq!(finals, vec![0, 3]);
}

#[test]
fn load_balance_only_reconfig() {
    // same instance set, new mapper: no membership changes, still atomic
    let tuples = gen_join(34, 1500, 0);
    let oracle = join_oracle(&tuples, 60);
    let (got, completions, finals) =
        run_elastic(&tuples, 60, 2, 4, &[(700, vec![0, 1])], oracle.len());
    assert_eq!(got, oracle);
    assert_eq!(completions.len(), 1);
    assert_eq!(finals, vec![0, 1]);
}

#[test]
fn reconfiguration_time_under_40ms() {
    // The paper's headline: reconfigurations < 40 ms even provisioning
    // tens of instances. On this container we provision 1 → 4.
    let tuples = gen_join(35, 4000, 0);
    let oracle = join_oracle(&tuples, 40);
    let (_, completions, _) =
        run_elastic(&tuples, 40, 1, 6, &[(2000, vec![0, 1, 2, 3, 4, 5])], oracle.len());
    assert_eq!(completions.len(), 1);
    let (_, ms) = completions[0];
    // The paper bound (40 ms) is asserted in release benches; debug builds
    // on a 1-core container get slack for the unoptimized hot path.
    let bound = if cfg!(debug_assertions) { 250.0 } else { 40.0 };
    assert!(ms < bound, "reconfiguration took {ms:.2} ms (bound: {bound} ms)");
}

#[test]
fn state_is_not_transferred() {
    // The shared σ object is the same Arc before and after reconfigs —
    // this is structural in VSN, but assert the externally visible part:
    // a reconfiguration completes while the window holds live state, and
    // counts seen by instances stay consistent (no resets, no double
    // counting → oracle equality in the other tests). Here: reconfig with
    // a *huge* in-flight window, then verify continued matching.
    let mut tuples = gen_join(36, 800, 0);
    tuples.extend(gen_join(37, 800, tuples.last().unwrap().ts));
    let oracle = join_oracle(&tuples, 2000); // window spans the reconfig
    let (got, completions, _) = run_elastic(&tuples, 2000, 1, 4, &[(800, vec![1, 2])], oracle.len());
    assert_eq!(got, oracle, "pre-reconfig state must remain visible to new owners");
    assert_eq!(completions.len(), 1);
}

#[test]
fn pooled_run_buffers_survive_reconfig_and_crash_without_leaks() {
    // §Perf memory discipline: worker run buffers are drawn from the
    // gate pools and handed back at thread exit, across the full
    // elastic lifecycle — grow, injected crash, healing shrink (zombie
    // replay + decommission). An `Arc` payload makes every surviving
    // clone countable: after the engine and all handles drop, exactly
    // the test's own reference may remain. A residual clone would mean
    // a recycled buffer aliased tuples into a successor (`put` failed
    // to clear) or a pooled buffer leaked a payload past shutdown.
    let marker = Arc::new(0u64);
    let def = OperatorDef::from_fn(
        "idarc",
        64,
        |t: &Tuple<Arc<u64>>, emit: &mut dyn FnMut(Arc<u64>)| emit(t.payload.clone()),
    );
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: 2, max: 4, upstreams: 1, gate_capacity: 4096, ..Default::default() },
    );
    let control = engine.control.clone();
    let health = engine.health();
    let mut ing = ingress.remove(0);
    let mut reader = readers.remove(0);

    // Single-threaded feeding is safe: 1200 in + 1200 out < 4096, so
    // flow control never blocks the feeder against the undrained egress.
    let mut ts = 0i64;
    let feed = |ing: &mut stretch::engine::StretchIngress<Arc<u64>>, ts: &mut i64, n: usize, m: &Arc<u64>| {
        for _ in 0..n {
            *ts += 1;
            ing.add(Tuple::data(*ts, m.clone())).unwrap();
        }
    };

    feed(&mut ing, &mut ts, 400, &marker);
    // grow 2 → 4: pool instances activate and start drawing batches
    control.reconfigure(vec![0, 1, 2, 3], Mapper::over(vec![0, 1, 2, 3]));
    feed(&mut ing, &mut ts, 400, &marker);
    // crash worker 3 at its next batch boundary → zombie with a pinned
    // unprocessed share
    health.inject(3, InjectedFault::Kill);
    // healing shrink 4 → 2: replays the dead slot's share, then the
    // decommissioned zombie exits and returns its run buffers
    control.reconfigure(vec![0, 1], Mapper::over(vec![0, 1]));
    feed(&mut ing, &mut ts, 400, &marker);
    ing.heartbeat(10_000_000).unwrap();

    // exactly-once across the grow, the crash, and the healing shrink:
    // 1200 data outputs, no more (aliasing would duplicate), no fewer
    let mut got = 0usize;
    let deadline = std::time::Instant::now() + Duration::from_secs(40);
    while got < 1200 && std::time::Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => got += 1,
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    assert_eq!(got, 1200, "exactly-once across reconfigs + crash replay");
    // no spurious extra outputs trailing behind the expected count
    let quiet = std::time::Instant::now() + Duration::from_millis(200);
    while std::time::Instant::now() < quiet {
        if let Some(t) = reader.get() {
            assert!(!t.kind.is_data(), "duplicate data output after tuple 1200");
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let t0 = std::time::Instant::now();
    while engine.control.completion_times().len() < 2 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(engine.control.completion_times().len(), 2, "both reconfigs must complete");

    engine.shutdown();
    // every worker thread (live, evicted, and the healed zombie) handed
    // its two run buffers back to the gate pools on exit
    assert!(
        engine.esg_in.pool().pooled() >= 4,
        "in-gate pool holds {} buffers, want the 4 worker batch buffers",
        engine.esg_in.pool().pooled()
    );
    assert!(
        engine.esg_out.pool().pooled() >= 4,
        "out-gate pool holds {} buffers, want the 4 worker out_bufs",
        engine.esg_out.pool().pooled()
    );
    drop(reader);
    drop(ing);
    drop(readers);
    drop(ingress);
    drop(engine);
    // pooled buffers are cleared at put-time and gate logs dropped with
    // the engine: no payload clone may survive anywhere
    assert_eq!(Arc::strong_count(&marker), 1, "payload clones leaked past engine teardown");
}
