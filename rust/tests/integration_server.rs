//! Integration: the multi-job server layer (harness/server.rs).
//!
//! Two proofs. First, the control surface is genuinely concurrent: many
//! threads hammer one live job with `scale_to` and every ticket reaches
//! a terminal outcome while the output stays exactly equal to the
//! sequential reference. Second, the fleet layer: two diamond jobs on
//! ONE runtime thread under ONE core budget deliberately smaller than
//! the sum of their maxima — the [`stretch::elastic::ServerController`]
//! must move cores between the hot and the idle job through ordinary
//! epoch reconfigurations, a third job must be refused admission, and
//! BOTH jobs' egress multisets must still equal their oracles exactly.

use std::time::{Duration, Instant};

use stretch::config::Config;
use stretch::elastic::JobShare;
use stretch::engine::JobSpec;
use stretch::harness::{
    Admission, Job, JobServer, LaunchConfig, ReplaySource, TicketOutcome,
};
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{hedge_diamond_oracle, NyseConfig, Trade, TradeStream};
use stretch::workloads::rates::RateSchedule;
use stretch::workloads::registry::{into_job_tuple, JobPayload};

const WS_MS: i64 = 800;

type Match = (u16, i32, u16, i32);

/// A trade corpus plus its sequential-reference match multiset.
/// `trade_rate` shapes the event timestamps, so corpora generated at
/// different rates window differently — two jobs fed from different
/// corpora would expose any cross-job tuple leakage as a multiset
/// mismatch.
fn diamond_corpus(n: usize, trade_rate: f64) -> (Vec<Tuple<Trade>>, Vec<Match>) {
    let cfg = NyseConfig { symbols: 8, ..Default::default() };
    let mut stream = TradeStream::new(&cfg, trade_rate);
    let trades: Vec<Tuple<Trade>> = (0..n).map(|_| stream.next()).collect();
    let mut oracle: Vec<Match> = hedge_diamond_oracle(&trades, WS_MS)
        .into_iter()
        .map(|h| (h.l_id, h.l_price, h.r_id, h.r_price))
        .collect();
    oracle.sort_unstable();
    assert!(!oracle.is_empty(), "degenerate corpus: no hedge matches");
    (trades, oracle)
}

fn extract_job(p: &JobPayload) -> Match {
    match p {
        JobPayload::Hedge(h) => (h.l_id, h.l_price, h.r_id, h.r_price),
        other => panic!("diamond sink must emit hedge matches, got {other:?}"),
    }
}

/// The config-built diamond, starting narrow (one instance per stage)
/// with room to stretch to 3 — Σ max = 12 cores.
const NARROW_DIAMOND: &str = r#"
[topology]
stages = ["filter", "left", "right", "join"]
edges = ["filter -> left", "filter -> right", "left -> join", "right -> join"]
[stage.filter]
operator = "trade-filter"
initial = 1
max = 3
gate_capacity = 8192
[stage.left]
operator = "left-leg"
initial = 1
max = 3
gate_capacity = 8192
[stage.right]
operator = "right-leg"
initial = 1
max = 3
gate_capacity = 8192
[stage.join]
operator = "hedge-join"
ws_ms = 800
keys = 32
initial = 1
max = 3
gate_capacity = 8192
"#;

/// The same diamond starting WIDE (two instances per stage, 8 cores) —
/// under a contended budget the fleet arbiter must shrink it back.
const WIDE_DIAMOND: &str = r#"
[topology]
stages = ["filter", "left", "right", "join"]
edges = ["filter -> left", "filter -> right", "left -> join", "right -> join"]
[stage.filter]
operator = "trade-filter"
initial = 2
max = 3
gate_capacity = 8192
[stage.left]
operator = "left-leg"
initial = 2
max = 3
gate_capacity = 8192
[stage.right]
operator = "right-leg"
initial = 2
max = 3
gate_capacity = 8192
[stage.join]
operator = "hedge-join"
ws_ms = 800
keys = 32
initial = 2
max = 3
gate_capacity = 8192
"#;

/// Build a replay-fed, egress-capturing diamond [`Job`] from a config
/// string — the `Job<JobPayload, JobPayload>` shape [`JobServer::submit`]
/// takes.
fn diamond_job(conf: &str, name: &str, trades: &[Tuple<Trade>], rate: f64) -> Job<JobPayload, JobPayload> {
    let spec = JobSpec::from_config(&Config::parse(conf).unwrap()).expect("job config is valid");
    let built = spec.build().expect("diamond job builds");
    let tuples: Vec<Tuple<JobPayload>> =
        trades.iter().cloned().map(into_job_tuple::<Trade>).collect();
    Job::new(built.pipeline, ReplaySource::new(tuples)).with_config(LaunchConfig {
        name: name.into(),
        schedule: RateSchedule::constant(60, rate),
        time_scale: 2.0,
        flush_slack_ms: WS_MS + 10_000,
        drain: Duration::from_millis(300),
        capture_egress: true,
        ..Default::default()
    })
}

/// The control surface under contention: three threads share one job's
/// [`stretch::harness::JobCtl`] (it is `Clone` by design) and issue 72
/// overlapping `scale_to` calls across every stage while the corpus
/// replays. Every ticket must reach a terminal outcome — Completed,
/// Rejected (post-EOS stragglers) or Abandoned (superseded by a rival
/// thread's scale on the same stage) — and the egress multiset must
/// still equal the sequential reference exactly.
#[test]
fn tickets_from_many_threads_all_resolve_and_output_stays_exact() {
    let (trades, oracle) = diamond_corpus(2_000, 1_000.0);
    let handle = diamond_job(WIDE_DIAMOND, "ticket-storm", &trades, 1_000.0)
        .launch()
        .expect("diamond launches");

    let mut writers = Vec::new();
    for w in 0..3usize {
        let ctl = handle.ctl();
        writers.push(std::thread::spawn(move || {
            let sets: [&[usize]; 3] = [&[0], &[0, 1], &[0, 1, 2]];
            let mut tickets = Vec::new();
            for round in 0..6usize {
                for stage in 0..4usize {
                    let set = sets[(w + round + stage) % sets.len()].to_vec();
                    tickets.push(ctl.scale_to(stage, set));
                }
                std::thread::sleep(Duration::from_millis(40));
            }
            tickets
        }));
    }
    let mut tickets = Vec::new();
    for t in writers {
        tickets.extend(t.join().expect("writer thread panicked"));
    }
    assert_eq!(tickets.len(), 72);
    for t in &tickets {
        assert!(
            t.wait_outcome(Duration::from_secs(30)).is_some(),
            "concurrently issued ticket for stage {} never resolved: {t:?}",
            t.stage()
        );
    }
    assert!(
        tickets.iter().any(|t| matches!(t.outcome(), Some(TicketOutcome::Completed(_)))),
        "no concurrent reconfiguration ever completed"
    );

    handle.await_quiesce();
    let mut got: Vec<Match> = handle
        .take_egress()
        .iter()
        .filter(|t| t.kind.is_data())
        .map(|t| extract_job(&t.payload))
        .collect();
    let outcome = handle.shutdown();
    assert_eq!(outcome.result.ingress_dropped, 0, "replay must not lose tuples");
    // the shutdown-idempotence fix: a second shutdown (or a later Drop)
    // returns the cached outcome instead of tearing down twice
    let again = handle.shutdown();
    assert_eq!(again.result.egress_count, outcome.result.egress_count);

    got.sort_unstable();
    assert_eq!(got, oracle, "ticket storm diverged from the sequential reference");
}

/// The fleet acceptance proof: a hot narrow diamond and an idle wide
/// diamond under a 10-core budget (Σ per-job maxima = 24; the fleet even
/// STARTS over budget at 4 + 8 = 12 cores). The arbiter must force the
/// fleet under the budget — every move an ordinary epoch
/// reconfiguration on one stage of one job — a third diamond must be
/// refused admission with a reasoned error, per-job stops must be
/// idempotent, and both jobs' multisets must equal their own oracles
/// exactly (the corpora differ, so any cross-job leakage shows).
#[test]
fn two_job_server_rebalances_under_one_budget_and_preserves_both_multisets() {
    let (hot_trades, hot_oracle) = diamond_corpus(2_400, 1_000.0);
    let (idle_trades, idle_oracle) = diamond_corpus(1_200, 600.0);
    assert_ne!(hot_oracle, idle_oracle, "corpora must be distinguishable");

    let server = JobServer::new(10)
        .with_period(Duration::from_millis(50))
        .with_thresholds(256, 64)
        .with_cooldown(0);
    assert_eq!(server.budget(), 10);

    // hot: 3 000 t/s wall against one instance per stage — starved for
    // cores. idle: 600 t/s wall against two per stage — over-provisioned.
    let hot = server
        .submit(
            diamond_job(NARROW_DIAMOND, "hot", &hot_trades, 1_500.0),
            JobShare { weight: 2.0, min_cores: 4 },
        )
        .expect("hot diamond admits (4 of 10 cores)");
    let idle = server
        .submit(
            diamond_job(WIDE_DIAMOND, "idle", &idle_trades, 300.0),
            JobShare { weight: 1.0, min_cores: 4 },
        )
        .expect("idle diamond admits (8 of 10 cores committed)");
    assert_ne!(hot, idle);

    // 8 of 10 cores are committed: a third 4-stage diamond cannot fit
    let Admission::Rejected { reason } = server
        .submit(
            diamond_job(NARROW_DIAMOND, "third", &hot_trades[..200], 1_000.0),
            JobShare { weight: 1.0, min_cores: 4 },
        )
        .expect_err("a third diamond must be refused admission");
    assert!(reason.contains("budget"), "rejection must name the budget: {reason}");

    // the fleet starts over budget (12 active > 10): the arbiter's
    // forced-fit wave must shrink it under — deterministic proof that at
    // least one cross-job rebalance happens while both jobs run
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = server.metrics();
        assert_eq!(m.budget, 10);
        assert_eq!(m.jobs.len(), 2, "both jobs must stay visible until stopped");
        if m.used_cores <= m.budget && m.used_cores >= 8 {
            break; // shrunk to fit, floors (4 + 4) respected
        }
        assert!(
            Instant::now() < deadline,
            "fleet never shrank to the budget: {} cores used",
            m.used_cores
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    let hot_out = server.stop(hot).expect("hot job stops");
    assert_eq!(hot_out.result.ingress_dropped, 0, "hot replay must not lose tuples");
    let idle_out = server.stop(idle).expect("idle job stops");
    assert_eq!(idle_out.result.ingress_dropped, 0, "idle replay must not lose tuples");
    // stop is idempotent: the second call returns the cached outcome
    let again = server.stop(hot).expect("second stop returns the cached outcome");
    assert_eq!(again.result.egress_count, hot_out.result.egress_count);

    // egress survives stop — the handle retains the captured tail
    let mut hot_got: Vec<Match> = server
        .take_egress(hot)
        .iter()
        .filter(|t| t.kind.is_data())
        .map(|t| extract_job(&t.payload))
        .collect();
    let mut idle_got: Vec<Match> = server
        .take_egress(idle)
        .iter()
        .filter(|t| t.kind.is_data())
        .map(|t| extract_job(&t.payload))
        .collect();

    let out = server.shutdown();
    assert_eq!(out.budget, 10);
    assert_eq!(out.jobs.len(), 2);
    assert_eq!(out.jobs[0].0, hot);
    assert_eq!(out.jobs[0].1.name, "hot");
    assert_eq!(out.jobs[1].0, idle);
    assert_eq!(out.jobs[1].1.name, "idle");

    assert!(!out.rebalances.is_empty(), "the fleet arbiter never rebalanced");
    // the over-provisioned idle job is the only one above its floor, so
    // the forced shrink MUST have landed on it
    assert!(
        out.rebalances.iter().any(|rb| rb.job == idle),
        "the idle job must give up cores under contention"
    );
    for rb in &out.rebalances {
        assert!(rb.stage < 4, "stage index out of range: {}", rb.stage);
        assert!(rb.job == hot || rb.job == idle);
        assert_eq!(rb.job_name, if rb.job == hot { "hot" } else { "idle" });
        assert!(
            rb.ticket.wait_outcome(Duration::from_secs(5)).is_some(),
            "cross-job rebalance on {} stage {} never resolved",
            rb.job_name,
            rb.stage
        );
    }
    assert!(
        out.rebalances.iter().any(|rb| rb.ticket.latency_ms().is_some()),
        "no cross-job rebalance ever completed with a measured latency"
    );

    hot_got.sort_unstable();
    idle_got.sort_unstable();
    assert_eq!(hot_got.len(), hot_oracle.len(), "hot match count diverged");
    assert_eq!(hot_got, hot_oracle, "hot job diverged from its sequential reference");
    assert_eq!(idle_got.len(), idle_oracle.len(), "idle match count diverged");
    assert_eq!(idle_got, idle_oracle, "idle job diverged from its sequential reference");
}
