"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps values, padding amounts and shape variants; exact
equality is required for the boolean masks and integer counts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import band_join, hedge, ref, window_count

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")


def pad_window(a, tile, fill):
    n = len(a)
    padded = ((n + tile - 1) // tile) * tile
    return np.concatenate([a, np.full(padded - n, fill, dtype=a.dtype)])


floats = st.floats(min_value=-1e4, max_value=1e4, width=32)


# ---------------------------------------------------------------- band join
@given(
    b=st.integers(1, 16),
    w=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_band_join_matches_ref(b, w, seed):
    rng = np.random.default_rng(seed)
    px = rng.uniform(0, 100, b).astype(np.float32)
    py = rng.uniform(0, 100, b).astype(np.float32)
    wa = pad_window(rng.uniform(0, 100, w).astype(np.float32), band_join.TILE_W, np.inf)
    wb = pad_window(rng.uniform(0, 100, w).astype(np.float32), band_join.TILE_W, np.inf)
    got = np.asarray(band_join.band_join_mask(px, py, wa, wb))
    want = np.asarray(ref.band_join_ref(jnp.asarray(px), jnp.asarray(py),
                                        jnp.asarray(wa), jnp.asarray(wb))).astype(np.int8)
    np.testing.assert_array_equal(got, want)
    # padded slots never match
    assert not got[:, w:].any()


def test_band_join_boundary_inclusive():
    px = np.array([0.0], dtype=np.float32)
    py = np.array([0.0], dtype=np.float32)
    wa = pad_window(np.array([10.0, 10.0001, -10.0], dtype=np.float32), band_join.TILE_W, np.inf)
    wb = pad_window(np.array([0.0, 0.0, 0.0], dtype=np.float32), band_join.TILE_W, np.inf)
    got = np.asarray(band_join.band_join_mask(px, py, wa, wb))[0]
    assert got[0] == 1  # |0-10| <= 10 inclusive
    assert got[1] == 0
    assert got[2] == 1


@given(b=st.integers(1, 8), w=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_band_join_counts_match_mask(b, w, seed):
    rng = np.random.default_rng(seed)
    px = rng.uniform(0, 50, b).astype(np.float32)
    py = rng.uniform(0, 50, b).astype(np.float32)
    wa = pad_window(rng.uniform(0, 50, w).astype(np.float32), band_join.TILE_W, np.inf)
    wb = pad_window(rng.uniform(0, 50, w).astype(np.float32), band_join.TILE_W, np.inf)
    counts = np.asarray(band_join.band_join_counts(px, py, wa, wb))
    mask = np.asarray(band_join.band_join_mask(px, py, wa, wb))
    np.testing.assert_array_equal(counts, mask.sum(axis=1))


# ------------------------------------------------------------------- hedge
@given(
    b=st.integers(1, 16),
    w=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_hedge_matches_ref(b, w, seed):
    rng = np.random.default_rng(seed)
    p_nd = rng.uniform(-0.1, 0.1, b).astype(np.float32)
    p_id = rng.integers(0, 10, b).astype(np.int32)
    w_nd = pad_window(rng.uniform(-0.1, 0.1, w).astype(np.float32), hedge.TILE_W, 0.0)
    w_id = pad_window(rng.integers(0, 10, w).astype(np.int32), hedge.TILE_W, -1)
    got = np.asarray(hedge.hedge_mask(p_nd, p_id, w_nd, w_id))
    want = np.asarray(ref.hedge_ref(jnp.asarray(p_nd), jnp.asarray(p_id),
                                    jnp.asarray(w_nd), jnp.asarray(w_id))).astype(np.int8)
    np.testing.assert_array_equal(got, want)
    assert not got[:, w:].any()


def test_hedge_semantics_spotcheck():
    # nd ratio -1.0, distinct ids → match; same id → no match;
    # ratio -2.0 → out of band; same sign → no match
    p_nd = np.array([0.05, 0.05, 0.10, 0.05], dtype=np.float32)
    p_id = np.array([1, 2, 1, 1], dtype=np.int32)
    w_nd = pad_window(np.array([-0.05], dtype=np.float32), hedge.TILE_W, 0.0)
    w_id = pad_window(np.array([2], dtype=np.int32), hedge.TILE_W, -1)
    got = np.asarray(hedge.hedge_mask(p_nd, p_id, w_nd, w_id))[:, 0]
    assert got.tolist() == [1, 0, 0, 1]


# ------------------------------------------------------------ window count
@given(
    n=st.integers(1, 2000),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_count_matches_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    keys = pad_window(rng.integers(0, k, n).astype(np.int32), window_count.TILE_N, -1)
    got = np.asarray(window_count.window_count(keys, k))
    want = np.asarray(ref.window_count_ref(jnp.asarray(keys), k))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == n  # every non-padding key lands in exactly one bucket


def test_window_count_multi_tile_accumulates():
    n = window_count.TILE_N * 3
    keys = np.zeros(n, dtype=np.int32)
    got = np.asarray(window_count.window_count(keys, 4))
    assert got[0] == n and got[1:].sum() == 0


# -------------------------------------------------- AOT entries all lower
def test_aot_entries_lower():
    from compile import model
    from compile.aot import to_hlo_text

    for entry in model.aot_entries():
        name, fn, args = entry[0], entry[1], entry[2]
        kwargs = entry[3] if len(entry) > 3 else {}
        text = to_hlo_text(fn.lower(*args, **kwargs))
        assert "HloModule" in text, name
        assert len(text) > 200, name
