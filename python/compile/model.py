"""L2: the JAX compute graphs that the rust coordinator executes via PJRT.

Each function composes the L1 Pallas kernels into the operator-level step
the L3 hot path offloads:

* `band_join_step`  — probe batch vs stored window: mask + per-probe counts
  (the ScaleJoin f_U comparison batch, Q3);
* `hedge_step`      — the NYSE predicate batch (Q6);
* `wordcount_step`  — per-key window counts over a tile of key ids (Q1).

These are lowered ONCE by `compile.aot` to HLO text under artifacts/;
Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import band_join, hedge, window_count


def band_join_step(px, py, wa, wb):
    """ScaleJoin comparison batch.

    Returns (mask (B, W) int8, counts (B,) int32). The mask drives match
    emission on the rust side; the counts are the comparisons-metric
    reduction, fused by XLA into the same pass over the tile.
    """
    mask = band_join.band_join_mask(px, py, wa, wb, interpret=True)
    counts = jnp.sum(mask.astype(jnp.int32), axis=1)
    return mask, counts


def hedge_step(p_nd, p_id, w_nd, w_id):
    """NYSE hedge predicate batch: (mask (B, W) int8, counts (B,) int32)."""
    mask = hedge.hedge_mask(p_nd, p_id, w_nd, w_id, interpret=True)
    counts = jnp.sum(mask.astype(jnp.int32), axis=1)
    return mask, counts


def wordcount_step(keys, n_keys):
    """Windowed per-key counts over a tile of interned key ids."""
    return (window_count.window_count(keys, n_keys, interpret=True),)


# ---------------------------------------------------------------------------
# AOT variants: fixed shapes compiled once (PJRT executables are static).
# The rust offload engine picks the smallest variant that fits and pads.
# ---------------------------------------------------------------------------

# (batch, window) variants for the join kernels
JOIN_VARIANTS = [(16, 512), (16, 2048), (16, 8192)]
# (tile, keys) variants for the counting kernel
COUNT_VARIANTS = [(1024, 1024)]


def aot_entries():
    """Yield (name, jitted fn, example args) for every artifact."""
    f32 = jnp.float32
    i32 = jnp.int32
    for b, w in JOIN_VARIANTS:
        yield (
            f"band_join_b{b}_w{w}",
            jax.jit(band_join_step),
            (
                jax.ShapeDtypeStruct((b,), f32),
                jax.ShapeDtypeStruct((b,), f32),
                jax.ShapeDtypeStruct((w,), f32),
                jax.ShapeDtypeStruct((w,), f32),
            ),
        )
        yield (
            f"hedge_b{b}_w{w}",
            jax.jit(hedge_step),
            (
                jax.ShapeDtypeStruct((b,), f32),
                jax.ShapeDtypeStruct((b,), i32),
                jax.ShapeDtypeStruct((w,), f32),
                jax.ShapeDtypeStruct((w,), i32),
            ),
        )
    for n, k in COUNT_VARIANTS:
        yield (
            f"window_count_n{n}_k{k}",
            jax.jit(wordcount_step, static_argnames=("n_keys",)),
            (jax.ShapeDtypeStruct((n,), i32),),
            {"n_keys": k},
        )
