"""AOT lowering: JAX -> HLO text -> artifacts/ (build-time only).

HLO *text* is the interchange format, NOT `.serialize()`: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
A manifest (artifacts/manifest.txt) lists each executable with its
argument/result shapes so the rust runtime can validate at load time.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_sig(avals) -> str:
    parts = []
    for a in avals:
        dims = "x".join(str(d) for d in a.shape)
        parts.append(f"{a.dtype}[{dims}]")
    return ",".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for entry in model.aot_entries():
        name, fn, example_args = entry[0], entry[1], entry[2]
        kwargs = entry[3] if len(entry) > 3 else {}
        lowered = fn.lower(*example_args, **kwargs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        manifest.append(
            f"{name} args={shape_sig(example_args)} "
            f"outs={shape_sig(out_avals)}"
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} executables")


if __name__ == "__main__":
    main()
