"""L1 Pallas kernel: windowed per-key counting tile (wordcount, Q1).

Counts key occurrences over a tile of interned key ids. The TPU-shaped
formulation avoids scatter (no efficient scatter on the VPU): each grid
step compares a TILE_N slice of keys against the K bucket ids as an
equality matrix and accumulates column sums — O(N·K) element-wise work
that vectorizes perfectly, the classic small-K histogram trade.
Padding: key = -1 hits no bucket.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256


def _count_kernel(keys_ref, out_ref):
    step = pl.program_id(0)
    keys = keys_ref[...]  # (TILE_N,) i32
    k = out_ref.shape[0]
    buckets = jax.lax.broadcasted_iota(jnp.int32, (k,), 0)
    onehot = (keys[:, None] == buckets[None, :]).astype(jnp.int32)
    partial = jnp.sum(onehot, axis=0)  # (K,)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(step != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("n_keys", "interpret"))
def window_count(keys, n_keys, interpret=True):
    """Per-key counts: keys (N,) i32 (N multiple of TILE_N) -> (K,) i32."""
    n = keys.shape[0]
    assert n % TILE_N == 0, f"keys must be padded to {TILE_N}, got {n}"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_keys,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_keys,), jnp.int32),
        interpret=interpret,
    )(keys)
