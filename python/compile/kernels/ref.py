"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its reference here bit-for-bit
(boolean masks) or to float tolerance (reductions); pytest + hypothesis
sweep values, padding and shape variants against these.
"""

import jax.numpy as jnp

# §8.3 band: |x_L - a_R| <= 10 AND |y_L - b_R| <= 10
BAND = 10.0


def band_join_ref(px, py, wa, wb):
    """Band-join mask: probes (B,) x window (W,) -> bool (B, W).

    Padding convention: pad window slots with +inf so no probe matches.
    """
    dx = jnp.abs(px[:, None] - wa[None, :])
    dy = jnp.abs(py[:, None] - wb[None, :])
    return (dx <= BAND) & (dy <= BAND)


def hedge_ref(p_nd, p_id, w_nd, w_id):
    """NYSE hedge predicate (§8.6): normalized-distance ratio band.

    A pair matches when the companies differ and ND_l / ND_r lies in
    [-1.05, -0.95] (negative correlation). Implemented without division:
    nd_l/nd_r in [-1.05,-0.95]  <=>  nd_l*nd_r < 0 (opposite sign) and
    |nd_l| between 0.95|nd_r| and 1.05|nd_r|.
    Padding: w_id = -1 never matches (p_id >= 0).
    """
    opposite = (p_nd[:, None] * w_nd[None, :]) < 0.0
    al = jnp.abs(p_nd)[:, None]
    ar = jnp.abs(w_nd)[None, :]
    in_band = (al >= 0.95 * ar) & (al <= 1.05 * ar)
    distinct = p_id[:, None] != w_id[None, :]
    valid = (w_id >= 0)[None, :]
    return opposite & in_band & distinct & valid


def window_count_ref(keys, n_keys):
    """Per-key counts over a tile of key ids: (N,) int32 -> (K,) int32.

    Padding: key = -1 contributes to no bucket.
    """
    onehot = keys[:, None] == jnp.arange(n_keys, dtype=keys.dtype)[None, :]
    return jnp.sum(onehot.astype(jnp.int32), axis=0)
