"""L1 Pallas kernel: the NYSE hedge predicate tile (§8.6).

Same tile structure as band_join (VPU element-wise compare over window
tiles); the predicate is the negative-correlation band on normalized
distances, with symbol-inequality and padding guards evaluated in-kernel.
Division-free formulation (see ref.hedge_ref): ratio in [-1.05, -0.95]
<=> opposite signs AND |nd_p| within [0.95, 1.05]·|nd_w|.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_W = 128


def _hedge_kernel(pnd_ref, pid_ref, wnd_ref, wid_ref, mask_ref):
    pnd = pnd_ref[...]  # (B,) f32 normalized distances of probes
    pid = pid_ref[...]  # (B,) i32 symbol ids
    wnd = wnd_ref[...]  # (TILE_W,)
    wid = wid_ref[...]
    opposite = (pnd[:, None] * wnd[None, :]) < 0.0
    al = jnp.abs(pnd)[:, None]
    ar = jnp.abs(wnd)[None, :]
    in_band = (al >= 0.95 * ar) & (al <= 1.05 * ar)
    distinct = pid[:, None] != wid[None, :]
    valid = (wid >= 0)[None, :]
    mask_ref[...] = (opposite & in_band & distinct & valid).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hedge_mask(p_nd, p_id, w_nd, w_id, interpret=True):
    """Hedge mask: probes (B,) x window (W, padded w_id=-1) -> (B, W) i8."""
    b = p_nd.shape[0]
    w = w_nd.shape[0]
    assert w % TILE_W == 0, f"window must be padded to {TILE_W}, got {w}"
    grid = (w // TILE_W,)
    return pl.pallas_call(
        _hedge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((TILE_W,), lambda i: (i,)),
            pl.BlockSpec((TILE_W,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b, TILE_W), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.int8),
        interpret=interpret,
    )(p_nd, p_id, w_nd, w_id)
