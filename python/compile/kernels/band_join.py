"""L1 Pallas kernel: the ScaleJoin band-join predicate tile (§8.3).

The paper's compute hot-spot is the Cartesian comparison loop inside
ScaleJoin's f_U; its throughput metric *is* comparisons/second. On TPU we
evaluate a (B probes x W window) tile per grid step:

* window columns (a, b) are tiled HBM->VMEM via BlockSpec in chunks of
  TILE_W lanes (128-multiples for the VPU);
* the band predicate |px-a|<=10 & |py-b|<=10 is an element-wise compare
  on the VPU (this is not a matmul: the MXU is the wrong unit; the
  roofline is VPU/bandwidth-bound — DESIGN.md §Hardware-Adaptation);
* the mask is written back per tile; per-probe match counts are reduced
  in the same pass.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VPU lane-aligned window tile.
TILE_W = 128


def _band_kernel(px_ref, py_ref, wa_ref, wb_ref, mask_ref):
    """One (B, TILE_W) tile: vectorized band compare."""
    px = px_ref[...]  # (B,)
    py = py_ref[...]
    wa = wa_ref[...]  # (TILE_W,)
    wb = wb_ref[...]
    dx = jnp.abs(px[:, None] - wa[None, :])
    dy = jnp.abs(py[:, None] - wb[None, :])
    m = (dx <= ref.BAND) & (dy <= ref.BAND)
    mask_ref[...] = m.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def band_join_mask(px, py, wa, wb, interpret=True):
    """Band-join mask via the Pallas tile kernel.

    px, py: (B,) f32 probes. wa, wb: (W,) f32 stored window columns
    (padded to a TILE_W multiple with +inf). Returns (B, W) int8 mask.
    """
    b = px.shape[0]
    w = wa.shape[0]
    assert w % TILE_W == 0, f"window must be padded to {TILE_W}, got {w}"
    grid = (w // TILE_W,)
    return pl.pallas_call(
        _band_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((TILE_W,), lambda i: (i,)),
            pl.BlockSpec((TILE_W,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b, TILE_W), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.int8),
        interpret=interpret,
    )(px, py, wa, wb)


def band_join_counts(px, py, wa, wb, interpret=True):
    """Per-probe match counts (B,) int32 — the L2 reduction over the mask."""
    mask = band_join_mask(px, py, wa, wb, interpret=interpret)
    return jnp.sum(mask.astype(jnp.int32), axis=1)
