//! Quickstart: define an `A+` (multi-key aggregate), run it on the
//! STRETCH (VSN) engine, read results, then trigger a live elastic
//! reconfiguration — no state transfer, no stream interruption. Then the
//! two higher layers: declare a whole topology as 20 lines of config,
//! and drive a live job from your own code through `Job::launch`'s
//! `JobHandle` (scale with measured reconfig latencies, sample metrics,
//! quiesce, shut down). Then: kill a worker mid-run and watch the
//! supervisor heal it by reconfiguration alone. Then: install the
//! crate's counting allocator and watch the steady-state allocation
//! rate of the batched gate path converge to zero. Finally, the fleet
//! layer: TWO jobs on one runtime thread under one core budget, with a
//! `JobServer` re-arbitrating cores between them live and refusing a
//! third job that cannot fit.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Duration;
use stretch::engine::{VsnEngine, VsnOptions};
use stretch::metrics::CountingAlloc;
use stretch::operator::aggregate::count_per_key_op;
use stretch::time::WindowSpec;
use stretch::tuple::{Mapper, Tuple};

/// Count every heap allocation the example makes so step 11 can show the
/// run-buffer pools reaching their allocation-free steady state. The
/// counter is two relaxed atomic adds per alloc — cheap enough to leave
/// on for the whole example.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    // 1. An A+ operator: count occurrences per key over 10 s tumbling
    //    windows. Payloads carry their key set (f_MK just copies it) —
    //    one tuple can count toward MANY keys without duplication.
    let op = count_per_key_op::<Arc<Vec<u64>>, _>(
        "quickstart-count",
        WindowSpec::new(10_000, 10_000),
        |t, keys| keys.extend_from_slice(&t.payload),
    );

    // 2. setup(O+, m, n): 2 active instances, pool of 2 more (§7).
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        op,
        VsnOptions { initial: 2, max: 4, upstreams: 1, ..Default::default() },
    );
    let mut ing = ingress.remove(0);
    let mut out = readers.remove(0);

    // 3. Feed multi-key tuples: tags A/B/C with overlap.
    println!("feeding 9,000 tuples across two 10s windows...");
    for i in 0..9_000i64 {
        let keys: Vec<u64> = match i % 3 {
            0 => vec![1],          // "A"
            1 => vec![1, 2],       // "A" + "B"  (multi-key: no duplication!)
            _ => vec![2, 3],       // "B" + "C"
        };
        ing.add(Tuple::data(i * 2, Arc::new(keys))).unwrap(); // 2ms apart → 2 windows per 10s

        // 4. Mid-stream: provision instances 2 and 3 (epoch switch, <40ms,
        //    no state transfer — σ is shared).
        if i == 4_500 {
            let epoch = engine.control.reconfigure(vec![0, 1, 2, 3], Mapper::hash_mod(4));
            println!("  requested reconfiguration to Π=4 (epoch {epoch})");
        }
    }
    ing.heartbeat(1_000_000).unwrap(); // end-of-stream watermark

    // 5. Read the windowed counts.
    let mut results: Vec<(i64, u64, u64)> = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while std::time::Instant::now() < deadline {
        match out.get() {
            Some(t) if t.kind.is_data() => results.push((t.ts, t.payload.0, t.payload.1)),
            Some(_) => {}
            None => {
                if !results.is_empty() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    results.sort();
    println!("\nwindowed counts (window_end, key, count) — first 9:");
    for r in results.iter().take(9) {
        println!("  {:?}", r);
    }
    let total: u64 = results.iter().map(|r| r.2).sum();
    println!("  ... {} windows, {} total key-counts", results.len(), total);

    // 6. Confirm the reconfiguration happened and how long it took.
    for (epoch, ms) in engine.control.completion_times() {
        println!("reconfiguration to epoch {epoch} completed in {ms:.2} ms (paper bound: 40 ms)");
    }
    println!("final parallelism: Π = {}", engine.epoch_config().degree());
    engine.shutdown();

    declare_a_job_in_20_lines_of_config();
    drive_a_live_job_from_your_own_code();
    pin_the_data_plane_with_placement();
    kill_a_worker_and_watch_it_heal();
    watch_allocs_per_tuple_go_to_zero();
    run_two_jobs_under_one_budget();
}

/// 7. The declarative layer: a whole elastic TOPOLOGY — stages, edges,
///    per-stage parallelism, controller, adaptive batching — is ~20
///    lines of config, not Rust. The engine plans the shared gates and
///    control slots; `run_job` drives it under the `[run]` schedule.
///    (On disk this would be `stretch run my_job.conf`.)
fn declare_a_job_in_20_lines_of_config() {
    let job = stretch::config::Config::parse(
        r#"
name = "quickstart-job"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
max = 3
[stage.count]
operator = "word-count"
ws_ms = 1000
initial = 2
max = 4
[run]
duration_s = 3
rate = 500
time_scale = 3.0
[elastic]
controller = "dag"
cores = 4
[batch]
adaptive = true
"#,
    )
    .unwrap();
    println!("\ndeclarative job: tokenize → windowed wordcount, from 20 lines of config...");
    let out = stretch::harness::run_job(&job, None).unwrap_or_else(|e| panic!("job error: {e}"));
    for (name, s) in out.stage_names.iter().zip(&out.result.stages) {
        let last = s.samples.last();
        println!(
            "  stage {:<9} Π_final={} worker_batch={}",
            name,
            last.map(|x| x.threads).unwrap_or(0),
            last.map(|x| x.worker_batch).unwrap_or(0),
        );
    }
    println!(
        "  {} windowed counts at the egress — same engine, zero topology code",
        out.result.egress_count
    );
}

/// 8. The live runtime API: `Job::launch` owns the data plane (paced
///    feed, egress drain, metrics sampling) on a background thread and
///    hands back a `JobHandle` — your code is the elasticity *policy*:
///    it samples `JobMetrics`, calls `scale` (each call returns a
///    `ReconfigTicket` resolving to the measured reconfig latency), and
///    decides when to quiesce. The built-in controllers are wired
///    through exactly this surface.
fn drive_a_live_job_from_your_own_code() {
    use stretch::engine::pipeline::PipelineBuilder;
    use stretch::engine::VsnOptions;
    use stretch::harness::{Job, LaunchConfig};
    use stretch::time::WindowSpec;
    use stretch::workloads::tweets::{TweetGen, TweetGenConfig};
    use stretch::workloads::{tokenize_op, word_count_stage_op, RateSchedule};

    println!("\nlive job: tokenize → count, scaled from user code via the JobHandle...");
    let pipeline = PipelineBuilder::new(
        tokenize_op(64),
        VsnOptions { initial: 1, max: 3, ..Default::default() },
    )
    .stage(
        word_count_stage_op(WindowSpec::new(500, 500)),
        VsnOptions { initial: 1, max: 4, ..Default::default() },
    )
    .build();
    let source = TweetGen::new(TweetGenConfig { vocab: 2_000, seed: 11, ..Default::default() });
    let handle = Job::new(pipeline, source)
        .with_config(LaunchConfig {
            name: "quickstart-live".into(),
            schedule: RateSchedule::constant(3, 600.0),
            time_scale: 3.0,
            ..Default::default()
        })
        .launch()
        .expect("two-stage pipeline launches");

    // reconfigure both stages live; tickets carry the measured latency
    let tickets = [("tokenize", handle.scale(0, 3)), ("count", handle.scale(1, 2))];
    for (name, t) in &tickets {
        match t.wait(Duration::from_secs(30)) {
            Some(ms) => println!("  {name}: scaled in {ms:.2} ms (paper bound: 40 ms)"),
            None => println!("  {name}: reconfiguration did not complete"),
        }
    }
    let m = handle.sample();
    println!(
        "  live sample @ {:.1}s: Π = {:?}, backlog = {:?}",
        m.event_s,
        m.stages.iter().map(|s| s.active.len()).collect::<Vec<_>>(),
        m.stages.iter().map(|s| s.backlog).collect::<Vec<_>>(),
    );
    handle.await_quiesce();
    let out = handle.shutdown();
    println!(
        "  {} counts at the egress, {}/{} reconfig tickets resolved",
        out.result.egress_count,
        out.tickets.iter().filter(|t| t.latency_ms().is_some()).count(),
        out.tickets.len(),
    );
}

/// 9. The placement-aware data plane: `[placement] enabled = true` makes
///    the job discover the machine's socket/core topology and pin worker
///    threads, the runtime thread, and gate allocations (first touch) so
///    each stage's readers stay NUMA-local to their upstream's ESG_out.
///    Per-stage `cores = [..]` / `socket = N` override the planner; on a
///    single-socket or non-Linux box every pin degrades to a no-op, so
///    the same config runs everywhere. `bench_micro` measures what this
///    buys (`gate_local_tps` vs `gate_remote_tps` in `BENCH_micro.json`).
fn pin_the_data_plane_with_placement() {
    use stretch::runtime::CoreMap;

    let map = CoreMap::discover();
    println!(
        "\nplacement: {} core(s) on {} socket(s) visible to this process",
        map.len(),
        map.sockets()
    );
    let job = stretch::config::Config::parse(
        r#"
name = "quickstart-pinned"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
max = 2
[stage.count]
operator = "word-count"
ws_ms = 1000
max = 2
[run]
duration_s = 2
rate = 400
time_scale = 4.0
[placement]
enabled = true
"#,
    )
    .unwrap();
    let out = stretch::harness::run_job(&job, None).unwrap_or_else(|e| panic!("job error: {e}"));
    println!(
        "  pinned job done: {} counts at the egress — same topology, NUMA-local gates",
        out.result.egress_count
    );
}

/// 10. Self-healing: kill a worker mid-run and watch it heal. A
///     `[faults]` section scripts the crash; containment catches the
///     panic (the slot goes Dead but keeps its gate share), detection
///     flags it on the next runtime tick, and the supervisor — attached
///     automatically whenever `[faults]` is present — evicts the dead
///     worker through a NORMAL epoch switch: its zombie replays the
///     unprocessed share through the surviving epoch (no state
///     transfer), then the stage re-grows onto a fresh slot. Each
///     recovery is a ticket whose detection→healed latency is the
///     `mttr_ms` of `BENCH_<job>.json`.
fn kill_a_worker_and_watch_it_heal() {
    let job = stretch::config::Config::parse(
        r#"
name = "quickstart-chaos"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
initial = 2
max = 3
[stage.count]
operator = "word-count"
ws_ms = 500
initial = 2
max = 2
[run]
duration_s = 3
rate = 400
time_scale = 3.0
[faults]
steps = ["1 -> kill tokenize:0"]
"#,
    )
    .unwrap();
    println!("\nchaos: kill tokenize worker 0 at event second 1 and let the supervisor heal it...");
    let out = stretch::harness::run_job(&job, None).unwrap_or_else(|e| panic!("job error: {e}"));
    for r in &out.recoveries {
        let stage = &out.stage_names[r.stage()];
        match r.mttr_ms() {
            Some(ms) => println!(
                "  {stage} worker {} ({:?}): healed in {ms:.1} ms (detection → healed)",
                r.worker(),
                r.kind()
            ),
            None => println!("  {stage} worker {}: NOT healed before end-of-stream", r.worker()),
        }
    }
    println!(
        "  {} counts at the egress{} — crash recovery IS reconfiguration",
        out.result.egress_count,
        if out.degraded { " (job DEGRADED)" } else { "" }
    );
}

/// 11. The memory discipline, made visible: this example runs under the
///     crate's `CountingAlloc` (see the `#[global_allocator]` at the
///     top), so we can watch the batched-gate hot path settle into its
///     allocation-free steady state (§ "Perf: memory discipline" in the
///     crate docs). The first rounds allocate — the ESG ring, the merge
///     scratch, and the run-buffer pools all grow to their working set —
///     then every buffer recycles through the pools and the per-tuple
///     count drops to ≈0. `bench_micro` records the warm number as
///     `allocs_per_tuple_batched_gate`, and CI gates it at 1.2× because
///     allocation counts, unlike tuples/s, are deterministic on any
///     machine.
fn watch_allocs_per_tuple_go_to_zero() {
    use stretch::metrics::alloc_snapshot;

    const BATCH: usize = 256;
    const ROUNDS_PER_STEP: u64 = 16;
    let (_gate, mut src, mut rdr) = stretch::scalegate::scale_gate::<Tuple<u64>>(1, 1, 1 << 14);
    let mut ts = 0i64;
    let mut run: Vec<Tuple<u64>> = Vec::new();
    let mut out: Vec<Tuple<u64>> = Vec::new();
    println!("\nwatch allocs/tuple go to zero ({BATCH}-tuple runs through a batched gate):");
    for step in 0..5u64 {
        let before = alloc_snapshot();
        for _ in 0..ROUNDS_PER_STEP {
            for _ in 0..BATCH {
                ts += 1;
                run.push(Tuple::data(ts, 1));
            }
            src[0].add_batch(&mut run).unwrap();
            while rdr[0].get_batch(&mut out, BATCH) > 0 {}
            out.clear();
        }
        let d = alloc_snapshot().delta(before);
        let tuples = (ROUNDS_PER_STEP * BATCH as u64) as f64;
        println!(
            "  rounds {:>2}..{:>2}: {:.4} allocs/tuple, {:>7.1} bytes/tuple",
            step * ROUNDS_PER_STEP + 1,
            (step + 1) * ROUNDS_PER_STEP,
            d.allocs as f64 / tuples,
            d.bytes as f64 / tuples,
        );
    }
    println!("  cold rounds fill the pools; warm rounds recycle them — ≈0 is the contract");
}

/// 12. The fleet layer: run TWO jobs under ONE core budget. A
///     `JobServer` adopts every submitted job onto a single shared
///     runtime thread (a job costs a list entry, not a thread) and
///     re-runs the fleet arbiter's shrink-then-grant wave across every
///     (job, stage) pair each period — weighted by `JobShare::weight`,
///     floored by `min_cores`, forced to fit the budget. Every move
///     BETWEEN jobs is the same epoch reconfiguration a single job uses
///     to scale, so it lands in milliseconds with no state transfer. A
///     job whose minimum footprint cannot fit is refused at `submit` —
///     admission control, before it ever competes for cores. (On disk
///     this is a `[server]` + `[job.<name>]` config and
///     `stretch serve fleet.conf`.)
fn run_two_jobs_under_one_budget() {
    use stretch::elastic::JobShare;
    use stretch::engine::JobSpec;
    use stretch::harness::{Job, JobServer, LaunchConfig, ReplaySource};
    use stretch::workloads::registry::{into_job_tuple, JobPayload};
    use stretch::workloads::tweets::{TweetGen, TweetGenConfig};
    use stretch::workloads::RateSchedule;
    use stretch::tuple::Tuple;

    // the §7 wordcount, narrow (hot: starved at one instance per stage)
    const NARROW: &str = r#"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
initial = 1
max = 3
[stage.count]
operator = "word-count"
ws_ms = 1000
initial = 1
max = 4
"#;
    // ... and wide (idle: over-provisioned at two per stage)
    const WIDE: &str = r#"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
initial = 2
max = 3
[stage.count]
operator = "word-count"
ws_ms = 1000
initial = 2
max = 4
"#;

    let build = |conf: &str, name: &str, seed: u64, rate: f64| {
        let spec = JobSpec::from_config(&stretch::config::Config::parse(conf).unwrap())
            .expect("fleet job config is valid");
        let built = spec.build().expect("fleet job builds");
        let tweets: Vec<Tuple<JobPayload>> =
            TweetGen::new(TweetGenConfig { vocab: 500, seed, mean_gap_ms: 2.0, ..Default::default() })
                .take(2_000)
                .into_iter()
                .map(into_job_tuple)
                .collect();
        Job::new(built.pipeline, ReplaySource::new(tweets)).with_config(LaunchConfig {
            name: name.into(),
            schedule: RateSchedule::constant(10, rate),
            time_scale: 3.0,
            ..Default::default()
        })
    };

    // budget 4 < Σ per-job maxima (7 + 7); the fleet even STARTS over
    // budget (2 + 4 = 6 active), so the first wave must force it to fit
    println!("\ntwo jobs, one budget: a 4-core JobServer arbitrating hot vs idle...");
    let server = JobServer::new(4)
        .with_period(Duration::from_millis(100))
        .with_thresholds(512, 64)
        .with_cooldown(0);
    let hot = server
        .submit(build(NARROW, "hot", 7, 900.0), JobShare { weight: 2.0, min_cores: 2 })
        .expect("hot job admits (2 of 4 cores)");
    let idle = server
        .submit(build(WIDE, "idle", 13, 300.0), JobShare { weight: 1.0, min_cores: 2 })
        .expect("idle job admits (4 of 4 cores committed)");
    // the budget is spoken for: a third job is refused BEFORE launching
    if let Err(e) = server.submit(build(NARROW, "third", 17, 100.0), JobShare { weight: 1.0, min_cores: 2 }) {
        println!("  third job refused: {e}");
    }

    // drain each job (blocks until its replay quiesces), then the fleet
    for id in [hot, idle] {
        if let Some(out) = server.stop(id) {
            println!(
                "  {id} `{}`: {} counts at the egress, {} dropped",
                out.name, out.result.egress_count, out.result.ingress_dropped
            );
        }
    }
    let out = server.shutdown();
    println!(
        "  {} cross-job rebalance(s) — every move an ordinary epoch reconfiguration:",
        out.rebalances.len()
    );
    for rb in out.rebalances.iter().take(4) {
        match rb.ticket.latency_ms() {
            Some(ms) => println!(
                "    `{}` stage {} re-fit in {ms:.2} ms (paper bound: 40 ms)",
                rb.job_name, rb.stage
            ),
            None => println!("    `{}` stage {}: superseded before completing", rb.job_name, rb.stage),
        }
    }
}
