//! Quickstart: define an `A+` (multi-key aggregate), run it on the
//! STRETCH (VSN) engine, read results, then trigger a live elastic
//! reconfiguration — no state transfer, no stream interruption.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Duration;
use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::aggregate::count_per_key_op;
use stretch::time::WindowSpec;
use stretch::tuple::{Mapper, Tuple};

fn main() {
    // 1. An A+ operator: count occurrences per key over 10 s tumbling
    //    windows. Payloads carry their key set (f_MK just copies it) —
    //    one tuple can count toward MANY keys without duplication.
    let op = count_per_key_op::<Arc<Vec<u64>>, _>(
        "quickstart-count",
        WindowSpec::new(10_000, 10_000),
        |t, keys| keys.extend_from_slice(&t.payload),
    );

    // 2. setup(O+, m, n): 2 active instances, pool of 2 more (§7).
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        op,
        VsnOptions { initial: 2, max: 4, upstreams: 1, ..Default::default() },
    );
    let mut ing = ingress.remove(0);
    let mut out = readers.remove(0);

    // 3. Feed multi-key tuples: tags A/B/C with overlap.
    println!("feeding 9,000 tuples across two 10s windows...");
    for i in 0..9_000i64 {
        let keys: Vec<u64> = match i % 3 {
            0 => vec![1],          // "A"
            1 => vec![1, 2],       // "A" + "B"  (multi-key: no duplication!)
            _ => vec![2, 3],       // "B" + "C"
        };
        ing.add(Tuple::data(i * 2, Arc::new(keys))).unwrap(); // 2ms apart → 2 windows per 10s

        // 4. Mid-stream: provision instances 2 and 3 (epoch switch, <40ms,
        //    no state transfer — σ is shared).
        if i == 4_500 {
            let epoch = engine.control.reconfigure(vec![0, 1, 2, 3], Mapper::hash_mod(4));
            println!("  requested reconfiguration to Π=4 (epoch {epoch})");
        }
    }
    ing.heartbeat(1_000_000).unwrap(); // end-of-stream watermark

    // 5. Read the windowed counts.
    let mut results: Vec<(i64, u64, u64)> = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while std::time::Instant::now() < deadline {
        match out.get() {
            Some(t) if t.kind.is_data() => results.push((t.ts, t.payload.0, t.payload.1)),
            Some(_) => {}
            None => {
                if !results.is_empty() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    results.sort();
    println!("\nwindowed counts (window_end, key, count) — first 9:");
    for r in results.iter().take(9) {
        println!("  {:?}", r);
    }
    let total: u64 = results.iter().map(|r| r.2).sum();
    println!("  ... {} windows, {} total key-counts", results.len(), total);

    // 6. Confirm the reconfiguration happened and how long it took.
    for (epoch, ms) in engine.control.completion_times() {
        println!("reconfiguration to epoch {epoch} completed in {ms:.2} ms (paper bound: 40 ms)");
    }
    println!("final parallelism: Π = {}", engine.epoch_config().degree());
    engine.shutdown();

    declare_a_job_in_20_lines_of_config();
}

/// 7. The declarative layer: a whole elastic TOPOLOGY — stages, edges,
///    per-stage parallelism, controller, adaptive batching — is ~20
///    lines of config, not Rust. The engine plans the shared gates and
///    control slots; `run_job` drives it under the `[run]` schedule.
///    (On disk this would be `stretch run my_job.conf`.)
fn declare_a_job_in_20_lines_of_config() {
    let job = stretch::config::Config::parse(
        r#"
name = "quickstart-job"
[topology]
stages = ["tokenize", "count"]
edges = ["tokenize -> count"]
[stage.tokenize]
operator = "tweet-tokenize"
max = 3
[stage.count]
operator = "word-count"
ws_ms = 1000
initial = 2
max = 4
[run]
duration_s = 3
rate = 500
time_scale = 3.0
[elastic]
controller = "dag"
cores = 4
[batch]
adaptive = true
"#,
    )
    .unwrap();
    println!("\ndeclarative job: tokenize → windowed wordcount, from 20 lines of config...");
    let out = stretch::harness::run_job(&job, None).unwrap_or_else(|e| panic!("job error: {e}"));
    for (name, s) in out.stage_names.iter().zip(&out.result.stages) {
        let last = s.samples.last();
        println!(
            "  stage {:<9} Π_final={} worker_batch={}",
            name,
            last.map(|x| x.threads).unwrap_or(0),
            last.map(|x| x.worker_batch).unwrap_or(0),
        );
    }
    println!(
        "  {} windowed counts at the egress — same engine, zero topology code",
        out.result.egress_count
    );
}
