//! NYSE hedge detection (the Q6 scenario): self-join the synthetic trade
//! stream and report negatively-correlated (hedging) stock pairs.
//!
//! ```sh
//! cargo run --release --example nyse_hedge -- --duration 20
//! ```

use stretch::cli::OrExit;
use std::time::Duration;
use stretch::engine::{VsnEngine, VsnOptions};
use stretch::operator::join::{scalejoin_op, Either};
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{HedgePredicate, NyseConfig, NyseGen, Trade};

fn main() {
    let args = stretch::cli::Cli::new("nyse_hedge", "NYSE hedge self-join demo")
        .opt("duration", "trace seconds", Some("20"))
        .opt("peak", "peak rate t/s", Some("1500"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));

    let peak = args.f64_or("peak", 1500.0).or_exit();
    let cfg = NyseConfig {
        duration_s: args.u64_or("duration", 20).or_exit() as u32,
        peak_rate: peak,
        floor_rate: peak / 15.0,
        ..Default::default()
    };
    println!("generating {}s of synthetic NYSE trades ({} symbols)...", cfg.duration_s, cfg.symbols);
    let (rates, trades) = NyseGen::new(cfg).generate();
    println!(
        "  {} trades; rate range {:.0}-{:.0} t/s (bursty U-shape)",
        trades.len(),
        rates.iter().cloned().fold(f64::MAX, f64::min),
        rates.iter().cloned().fold(0.0, f64::max)
    );

    // WS = 30 s, self-join (§8.6): each trade feeds both inputs
    let def = scalejoin_op("hedge", 30_000, HedgePredicate, 64);
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: 2, max: 4, upstreams: 1, ..Default::default() },
    );
    let clock = engine.clock.clone();
    let mut ing = ingress.remove(0);
    let mut out = readers.remove(0);
    let feeder = std::thread::spawn(move || {
        for t in trades {
            let ingest = clock.now_us();
            ing.add(
                Tuple::data_on(t.ts, 0, Either::<Trade, Trade>::L(t.payload)).with_ingest(ingest),
            )
            .unwrap();
            ing.add(
                Tuple::data_on(t.ts, 1, Either::<Trade, Trade>::R(t.payload)).with_ingest(ingest),
            )
            .unwrap();
        }
        ing.heartbeat(i64::MAX / 16).unwrap();
    });
    let mut pair_counts = std::collections::HashMap::<(u16, u16), u64>::new();
    let mut total = 0u64;
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut quiet = std::time::Instant::now();
    while std::time::Instant::now() < deadline {
        match out.get() {
            Some(t) if t.kind.is_data() => {
                let h = t.payload;
                let pair = if h.l_id <= h.r_id { (h.l_id, h.r_id) } else { (h.r_id, h.l_id) };
                *pair_counts.entry(pair).or_default() += 1;
                total += 1;
                quiet = std::time::Instant::now();
            }
            Some(_) => {}
            None => {
                if feeder.is_finished() && quiet.elapsed() > Duration::from_millis(300) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    feeder.join().unwrap();
    let comparisons = engine.metrics.snapshot().comparisons;
    engine.shutdown();

    println!("\n{total} hedge signals from {comparisons} comparisons");
    let mut pairs: Vec<_> = pair_counts.into_iter().collect();
    pairs.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    println!("most-hedged symbol pairs:");
    for ((a, b), c) in pairs.iter().take(5) {
        println!("  sym{a} ↔ sym{b}: {c} co-movements");
    }
}
