//! Diamond DAG demo: trade filter → fan-out (left leg ∥ right leg) →
//! fan-in hedge join → sink, on TRUE shared-gate DAG plumbing — the
//! fan-out is two reader groups on one ESG_out, the fan-in two
//! source-slot groups on the join's ESG_in, and every stage has its own
//! per-edge control slot so all four reconfigure independently mid-run.
//! The final match multiset is checked for exact equivalence against a
//! single-threaded sequential reference.
//!
//! ```sh
//! cargo run --release --example diamond_dag -- --trades 4000
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stretch::engine::dag::DagBuilder;
use stretch::engine::VsnOptions;
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{
    hedge_diamond_oracle, hedge_join_op, left_leg_op, right_leg_op, trade_filter_op, HedgeOut,
    NyseConfig, Trade, TradeStream,
};

fn main() {
    let args = stretch::cli::Cli::new("diamond_dag", "diamond DAG (fan-out + fan-in) demo")
        .opt("trades", "corpus size", Some("4000"))
        .opt("ws", "join window (event ms)", Some("800"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("trades", 4_000);
    let ws_ms = args.u64_or("ws", 800) as i64;

    println!("═══ STRETCH diamond DAG: filter → (L-leg ∥ R-leg) → hedge join ═══\n");
    let cfg = NyseConfig { symbols: 8, ..Default::default() };
    let mut stream = TradeStream::new(&cfg, 1_000.0);
    let trades: Vec<Tuple<Trade>> = (0..n).map(|_| stream.next()).collect();
    let horizon = trades.last().unwrap().ts + ws_ms + 10_000;

    println!("[1/3] sequential reference: {n} trades, WS = {ws_ms} ms");
    let mut oracle: Vec<(u16, i32, u16, i32)> = hedge_diamond_oracle(&trades, ws_ms)
        .into_iter()
        .map(|h| (h.l_id, h.l_price, h.r_id, h.r_price))
        .collect();
    oracle.sort_unstable();
    println!("      {} hedge matches expected\n", oracle.len());

    // the diamond: one shared gate S→{L,R} (two reader groups), one
    // shared gate {L,R}→J (two source groups + J's control slot)
    let mut b = DagBuilder::<Trade, HedgeOut>::new();
    let s = b.source(
        trade_filter_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 1 << 14, ..Default::default() },
    );
    let l = b.node(
        left_leg_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 1 << 14, ..Default::default() },
        &[s],
    );
    let r = b.node(
        right_leg_op(64),
        VsnOptions { initial: 2, max: 2, gate_capacity: 1 << 14, ..Default::default() },
        &[s],
    );
    let j = b.node(
        hedge_join_op(ws_ms, 32),
        VsnOptions { initial: 1, max: 3, gate_capacity: 1 << 14, ..Default::default() },
        &[l, r],
    );
    let mut pipeline = b.build(&[j]).expect("diamond is a valid DAG");
    println!("[2/3] live run: {} stages, every stage reconfigured mid-run", pipeline.depth());

    let t0 = Instant::now();
    let progress = Arc::new(AtomicUsize::new(0));
    let feed = trades.clone();
    let mut ing = pipeline.ingress.remove(0);
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(t).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let mut reader = pipeline.egress.remove(0);
    let mut got: Vec<(u16, i32, u16, i32)> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut fired = [false; 4];
    let plan: [(usize, Vec<usize>, &str); 4] = [
        (0, vec![0, 1], "filter    Π 1 → 2"),
        (1, vec![0, 1], "left-leg  Π 1 → 2"),
        (2, vec![1], "right-leg Π 2 → 1"),
        (3, vec![0, 1, 2], "join      Π 1 → 3"),
    ];
    let mut buf: Vec<Tuple<HedgeOut>> = Vec::new();
    while got.len() < oracle.len() && Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        for (i, (stage, set, label)) in plan.iter().enumerate() {
            if !fired[i] && p > (i + 1) * n / 5 {
                let e = pipeline.reconfigure_stage(*stage, set.clone());
                println!("      @{p:>6} trades: stage {} {label}   (epoch {e})", stage + 1);
                fired[i] = true;
            }
        }
        buf.clear();
        if reader.get_batch(&mut buf, 256) == 0 {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        for t in &buf {
            if t.kind.is_data() {
                got.push((t.payload.l_id, t.payload.l_price, t.payload.r_id, t.payload.r_price));
            }
        }
    }
    feeder.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let tw = Instant::now();
    while pipeline.stages.iter().any(|s| s.completion_times().is_empty())
        && tw.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n[3/3] results:");
    let mut ok = true;
    for (k, stage) in pipeline.stages.iter().enumerate() {
        let m = stage.metrics().snapshot();
        let done = stage.completion_times().len();
        println!(
            "      stage {} ({:<12}) in={:>8} out={:>8} tuples, Π_final={}, reconfigs={}",
            k + 1,
            stage.name(),
            m.tuples_in,
            m.tuples_out,
            stage.active_instances().len(),
            done,
        );
        for (epoch, ms) in stage.completion_times() {
            let verdict = if ms < 40.0 { "✓ < 40 ms (paper bound)" } else { "" };
            println!("        reconfig epoch {epoch}: {ms:.2} ms {verdict}");
        }
        if done < 1 {
            ok = false;
        }
    }
    pipeline.shutdown();

    got.sort_unstable();
    if got == oracle {
        println!(
            "      ✓ output ≡ sequential reference ({} matches) in {wall:.2}s wall",
            oracle.len()
        );
    } else {
        println!(
            "      ✗ output diverged: got {} matches, expected {}",
            got.len(),
            oracle.len()
        );
        ok = false;
    }
    println!(
        "\n{}",
        if ok {
            "ALL FOUR STAGES RECONFIGURED INDEPENDENTLY, OUTPUT EXACT — diamond PASS"
        } else {
            "diamond FAIL — see above"
        }
    );
    if !ok {
        std::process::exit(1);
    }
}
