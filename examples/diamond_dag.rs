//! Diamond DAG demo, *declaratively*: the whole topology — trade filter
//! → fan-out (left leg ∥ right leg) → fan-in hedge join — comes from
//! `examples/configs/diamond.conf` via the JobSpec layer; this file
//! keeps only the payload-specific proof: feed a fixed trade corpus,
//! reconfigure every stage mid-run through its per-edge control slot,
//! and check the final match multiset for exact equivalence against a
//! single-threaded sequential reference.
//!
//! ```sh
//! cargo run --release --example diamond_dag -- --trades 4000
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stretch::cli::OrExit;
use stretch::config::Config;
use stretch::engine::JobSpec;
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{hedge_diamond_oracle, NyseConfig, Trade, TradeStream};
use stretch::workloads::registry::{into_job_tuple, JobPayload};

const DEFAULT_CONFIG: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/examples/configs/diamond.conf");

fn main() {
    let args = stretch::cli::Cli::new("diamond_dag", "declarative diamond DAG demo")
        .opt("trades", "corpus size", Some("4000"))
        .opt("config", "job config declaring the topology", Some(DEFAULT_CONFIG))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("trades", 4_000).or_exit();
    let path = args.str_or("config", DEFAULT_CONFIG);

    println!("═══ STRETCH diamond DAG (declared in {path}) ═══\n");
    let cfg = Config::load(path).unwrap_or_else(|e| panic!("config error: {e}"));
    let spec = JobSpec::from_config(&cfg).unwrap_or_else(|e| panic!("job error: {e}"));
    let ws_ms = spec
        .stages
        .iter()
        .find(|s| s.operator == "hedge-join")
        .map(|s| s.params.ws_ms)
        .expect("diamond config declares a hedge-join stage");

    let stream_cfg = NyseConfig {
        symbols: cfg.int_or("source.symbols", 8).max(1) as usize,
        ..Default::default()
    };
    let mut stream = TradeStream::new(&stream_cfg, 1_000.0);
    let trades: Vec<Tuple<Trade>> = (0..n).map(|_| stream.next()).collect();
    let horizon = trades.last().unwrap().ts + ws_ms + 10_000;

    println!("[1/3] sequential reference: {n} trades, WS = {ws_ms} ms");
    let mut oracle: Vec<(u16, i32, u16, i32)> = hedge_diamond_oracle(&trades, ws_ms)
        .into_iter()
        .map(|h| (h.l_id, h.l_price, h.r_id, h.r_price))
        .collect();
    oracle.sort_unstable();
    println!("      {} hedge matches expected\n", oracle.len());

    // the topology is a config: one build() call, zero wiring here
    let mut built = spec.build().unwrap_or_else(|e| panic!("job error: {e}"));
    let mut ing = built.pipeline.ingress.remove(0);
    println!(
        "[2/3] live run: {} stages ({}), every stage reconfigured mid-run",
        built.pipeline.depth(),
        built.stage_names.join(" → ")
    );

    let t0 = Instant::now();
    let progress = Arc::new(AtomicUsize::new(0));
    let feed = trades.clone();
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(into_job_tuple(t)).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let mut reader = built.pipeline.egress.remove(0);
    let mut got: Vec<(u16, i32, u16, i32)> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut fired = [false; 4];
    let plan: [(&str, Vec<usize>, &str); 4] = [
        ("filter", vec![0, 1], "filter    Π 1 → 2"),
        ("left", vec![0, 1], "left-leg  Π 1 → 2"),
        ("right", vec![1], "right-leg Π 2 → 1"),
        ("join", vec![0, 1, 2], "join      Π 1 → 3"),
    ];
    // the reconfig plan is part of this demo, the topology comes from
    // --config: fail up front if the config can't host the plan (an
    // instance id ≥ a stage's max would address another stage's slots)
    for (stage, set, _) in &plan {
        let st = spec
            .stages
            .iter()
            .find(|s| s.name == *stage)
            .unwrap_or_else(|| panic!("config must declare a `{stage}` stage for this demo"));
        let need = set.iter().max().unwrap() + 1;
        assert!(
            st.max >= need,
            "stage `{stage}` has max = {} but the demo's reconfig plan needs max ≥ {need}",
            st.max
        );
    }
    let mut buf: Vec<Tuple<JobPayload>> = Vec::new();
    while got.len() < oracle.len() && Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        for (i, (stage, set, label)) in plan.iter().enumerate() {
            if !fired[i] && p > (i + 1) * n / 5 {
                let k = built.stage_index(stage).expect("config names the stage");
                let e = built.pipeline.reconfigure_stage(k, set.clone());
                println!("      @{p:>6} trades: stage `{stage}` {label}   (epoch {e})");
                fired[i] = true;
            }
        }
        buf.clear();
        if reader.get_batch(&mut buf, 256) == 0 {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        for t in &buf {
            if t.kind.is_data() {
                match &t.payload {
                    JobPayload::Hedge(h) => {
                        got.push((h.l_id, h.l_price, h.r_id, h.r_price));
                    }
                    other => panic!("diamond sink must emit hedge matches, got {other:?}"),
                }
            }
        }
    }
    feeder.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let tw = Instant::now();
    while built.pipeline.stages.iter().any(|s| s.completion_times().is_empty())
        && tw.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n[3/3] results:");
    let mut ok = true;
    for (k, stage) in built.pipeline.stages.iter().enumerate() {
        let m = stage.metrics().snapshot();
        let done = stage.completion_times().len();
        println!(
            "      stage {} ({:<12}) in={:>8} out={:>8} tuples, Π_final={}, reconfigs={}",
            built.stage_names[k],
            stage.name(),
            m.tuples_in,
            m.tuples_out,
            stage.active_instances().len(),
            done,
        );
        for (epoch, ms) in stage.completion_times() {
            let verdict = if ms < 40.0 { "✓ < 40 ms (paper bound)" } else { "" };
            println!("        reconfig epoch {epoch}: {ms:.2} ms {verdict}");
        }
        if done < 1 {
            ok = false;
        }
    }
    built.pipeline.shutdown();

    got.sort_unstable();
    if got == oracle {
        println!(
            "      ✓ output ≡ sequential reference ({} matches) in {wall:.2}s wall",
            oracle.len()
        );
    } else {
        println!(
            "      ✗ output diverged: got {} matches, expected {}",
            got.len(),
            oracle.len()
        );
        ok = false;
    }
    println!(
        "\n{}",
        if ok {
            "CONFIG-DECLARED DIAMOND: ALL FOUR STAGES RECONFIGURED, OUTPUT EXACT — PASS"
        } else {
            "diamond FAIL — see above"
        }
    );
    if !ok {
        std::process::exit(1);
    }
}
