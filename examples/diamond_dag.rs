//! Diamond DAG demo, *declaratively and live*: the topology — trade
//! filter → fan-out (left leg ∥ right leg) → fan-in hedge join — comes
//! from `examples/configs/diamond.conf` via the JobSpec layer, and the
//! run is driven through the live runtime API: `Job::launch` owns the
//! feed/drain/sampling, while this file plays the external *policy* —
//! it watches `sample()`, issues `scale_to` calls mid-run (one per
//! stage, through each stage's per-edge control slot), reads every
//! reconfiguration's measured latency off its `ReconfigTicket`, and
//! checks the final match multiset for exact equivalence against a
//! single-threaded sequential reference.
//!
//! ```sh
//! cargo run --release --example diamond_dag -- --trades 4000
//! ```

use std::time::{Duration, Instant};

use stretch::cli::OrExit;
use stretch::config::Config;
use stretch::engine::JobSpec;
use stretch::harness::{Job, LaunchConfig, ReplaySource};
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{hedge_diamond_oracle, NyseConfig, Trade, TradeStream};
use stretch::workloads::rates::RateSchedule;
use stretch::workloads::registry::{into_job_tuple, JobPayload};

const DEFAULT_CONFIG: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/examples/configs/diamond.conf");

fn main() {
    let args = stretch::cli::Cli::new("diamond_dag", "declarative diamond DAG demo")
        .opt("trades", "corpus size", Some("4000"))
        .opt("config", "job config declaring the topology", Some(DEFAULT_CONFIG))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("trades", 4_000).or_exit();
    let path = args.str_or("config", DEFAULT_CONFIG);

    println!("═══ STRETCH diamond DAG (declared in {path}, driven live) ═══\n");
    let cfg = Config::load(path).unwrap_or_else(|e| panic!("config error: {e}"));
    let spec = JobSpec::from_config(&cfg).unwrap_or_else(|e| panic!("job error: {e}"));
    let ws_ms = spec
        .stages
        .iter()
        .find(|s| s.operator == "hedge-join")
        .map(|s| s.params.ws_ms)
        .expect("diamond config declares a hedge-join stage");

    let stream_cfg = NyseConfig {
        symbols: cfg.int_or("source.symbols", 8).max(1) as usize,
        ..Default::default()
    };
    let mut stream = TradeStream::new(&stream_cfg, 1_000.0);
    let trades: Vec<Tuple<Trade>> = (0..n).map(|_| stream.next()).collect();

    println!("[1/3] sequential reference: {n} trades, WS = {ws_ms} ms");
    let mut oracle: Vec<(u16, i32, u16, i32)> = hedge_diamond_oracle(&trades, ws_ms)
        .into_iter()
        .map(|h| (h.l_id, h.l_price, h.r_id, h.r_price))
        .collect();
    oracle.sort_unstable();
    println!("      {} hedge matches expected\n", oracle.len());

    // the reconfig plan is part of this demo, the topology comes from
    // --config: fail up front if the config can't host the plan (an
    // instance id ≥ a stage's max would address another stage's slots)
    let plan: [(&str, Vec<usize>, &str); 4] = [
        ("filter", vec![0, 1], "filter    Π 1 → 2"),
        ("left", vec![0, 1], "left-leg  Π 1 → 2"),
        ("right", vec![1], "right-leg Π 2 → 1"),
        ("join", vec![0, 1, 2], "join      Π 1 → 3"),
    ];
    for (stage, set, _) in &plan {
        let st = spec
            .stages
            .iter()
            .find(|s| s.name == *stage)
            .unwrap_or_else(|| panic!("config must declare a `{stage}` stage for this demo"));
        let need = set.iter().max().unwrap() + 1;
        assert!(
            st.max >= need,
            "stage `{stage}` has max = {} but the demo's reconfig plan needs max ≥ {need}",
            st.max
        );
    }

    // the topology is a config, the run is a launch: one build(), one
    // launch(), zero wiring here — the corpus replays through a
    // ReplaySource (exactly once, end-of-stream on exhaustion)
    let built = spec.build().unwrap_or_else(|e| panic!("job error: {e}"));
    let stage_names = built.stage_names.clone();
    let corpus: Vec<Tuple<JobPayload>> =
        trades.iter().cloned().map(into_job_tuple).collect();
    let t0 = Instant::now();
    // ~4k tuples per wall second: the corpus spans ~1 s of wall time, so
    // every feed-progress trigger fires comfortably before end-of-stream
    // (a scale issued after the EOS heartbeat could never complete)
    let handle = Job::new(built.pipeline, ReplaySource::new(corpus))
        .with_config(LaunchConfig {
            name: "diamond-live".into(),
            stage_names: stage_names.clone(),
            schedule: RateSchedule::constant(120, 2_000.0),
            time_scale: 2.0,
            flush_slack_ms: ws_ms + 10_000,
            drain: Duration::from_millis(300),
            capture_egress: true,
            ..Default::default()
        })
        .launch()
        .unwrap_or_else(|e| panic!("launch error: {e}"));
    println!(
        "[2/3] live run: {} stages ({}), every stage scaled through the JobHandle",
        handle.depth(),
        stage_names.join(" → ")
    );

    let stage_index = |name: &str| {
        stage_names.iter().position(|s| s == name).expect("config names the stage")
    };
    let mut fired = [false; 4];
    let mut tickets = Vec::new();
    let mut got: Vec<(u16, i32, u16, i32)> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    while Instant::now() < deadline {
        let m = handle.sample();
        for (i, (stage, set, label)) in plan.iter().enumerate() {
            if !fired[i] && m.fed > ((i + 1) * n / 5) as u64 {
                let ticket = handle.scale_to(stage_index(stage), set.clone());
                println!("      @{:>6} trades fed: stage `{stage}` {label}", m.fed);
                tickets.push(ticket);
                fired[i] = true;
            }
        }
        for t in handle.take_egress() {
            if t.kind.is_data() {
                match &t.payload {
                    JobPayload::Hedge(h) => got.push((h.l_id, h.l_price, h.r_id, h.r_price)),
                    other => panic!("diamond sink must emit hedge matches, got {other:?}"),
                }
            }
        }
        if (got.len() >= oracle.len() && fired.iter().all(|&f| f)) || handle.quiesced() {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n[3/3] results:");
    let mut ok = fired.iter().all(|&f| f);
    // each reconfiguration's measured latency, straight off its ticket
    for t in &tickets {
        match t.wait(Duration::from_secs(10)) {
            Some(ms) => {
                let verdict = if ms < 40.0 { "✓ < 40 ms (paper bound)" } else { "" };
                println!(
                    "      stage {:<8} epoch {:?}: reconfig {ms:.2} ms {verdict}",
                    stage_names[t.stage()],
                    t.epoch().unwrap_or(0),
                );
            }
            None => {
                println!("      stage {} reconfig NEVER COMPLETED", stage_names[t.stage()]);
                ok = false;
            }
        }
    }
    handle.await_quiesce();
    for t in handle.take_egress() {
        if t.kind.is_data() {
            if let JobPayload::Hedge(h) = &t.payload {
                got.push((h.l_id, h.l_price, h.r_id, h.r_price));
            }
        }
    }
    let final_m = handle.sample();
    let outcome = handle.shutdown();
    for ((name, s), live) in
        outcome.stage_names.iter().zip(&outcome.result.stages).zip(&final_m.stages)
    {
        println!(
            "      stage {:<8} ({:<12}) Π_final={} reconfigs={}",
            name,
            s.name,
            live.active.len(),
            s.reconfigs.len(),
        );
    }

    got.sort_unstable();
    if got == oracle {
        println!(
            "      ✓ output ≡ sequential reference ({} matches) in {wall:.2}s wall",
            oracle.len()
        );
    } else {
        println!(
            "      ✗ output diverged: got {} matches, expected {}",
            got.len(),
            oracle.len()
        );
        ok = false;
    }
    println!(
        "\n{}",
        if ok {
            "LIVE-DRIVEN DIAMOND: ALL FOUR STAGES SCALED THROUGH THE HANDLE, OUTPUT EXACT — PASS"
        } else {
            "diamond FAIL — see above"
        }
    );
    if !ok {
        std::process::exit(1);
    }
}
