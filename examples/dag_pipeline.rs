//! DAG pipeline demo: a two-stage VSN pipeline — tokenize Map → windowed
//! wordcount Aggregate — chained through ONE shared Elastic ScaleGate
//! (stage 1's ESG_out *is* stage 2's ESG_in; zero-copy hand-off, no
//! re-ingestion), with BOTH stages reconfigured independently at runtime
//! and the final output checked for exact equivalence against a
//! single-threaded sequential reference (no state transfer anywhere).
//!
//! ```sh
//! cargo run --release --example dag_pipeline -- --tweets 30000
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stretch::engine::pipeline::PipelineBuilder;
use stretch::engine::VsnOptions;
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::workloads::tweets::{
    tokenize_op, word_count_stage_op, wordcount_keys, Tweet, TweetGen, TweetGenConfig,
};

fn reference_counts(
    tuples: &[Tuple<Tweet>],
    spec: WindowSpec,
    horizon: i64,
) -> BTreeMap<(i64, Key), u64> {
    let mut m = BTreeMap::new();
    let mut keys = Vec::new();
    for t in tuples {
        keys.clear();
        wordcount_keys(t, &mut keys);
        let mut l = spec.earliest_win_l(t.ts);
        while l <= spec.latest_win_l(t.ts) {
            if l + spec.size <= horizon {
                for &k in &keys {
                    *m.entry((l + spec.size, k)).or_default() += 1;
                }
            }
            l += spec.advance;
        }
    }
    m
}

fn main() {
    let args = stretch::cli::Cli::new("dag_pipeline", "2-stage elastic VSN pipeline demo")
        .opt("tweets", "corpus size", Some("30000"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("tweets", 30_000);

    println!("═══ STRETCH multi-stage pipeline: tokenize → windowed wordcount ═══\n");
    let spec = WindowSpec::new(1_000, 1_000);
    let tuples = TweetGen::new(TweetGenConfig {
        vocab: 3_000,
        seed: 0xDA61,
        mean_gap_ms: 1.5,
        ..Default::default()
    })
    .take(n);
    let horizon = tuples.last().unwrap().ts + 30_000;
    println!("[1/3] sequential reference: {n} tweets, tumbling {} ms windows", spec.size);
    let oracle = reference_counts(&tuples, spec, horizon);
    println!("      {} (window, word) result entries expected\n", oracle.len());

    // stage 1: tokenize (Map as an elastic stage), Π: 1 of max 3
    // stage 2: windowed count (A+), Π: 2 of max 4 — note the shared gate:
    // stage 1's max workers + 1 control slot write it, stage 2's max read it
    let mut pipeline = PipelineBuilder::new(
        tokenize_op(64),
        VsnOptions { initial: 1, max: 3, gate_capacity: 1 << 14, ..Default::default() },
    )
    .stage(
        word_count_stage_op(spec),
        VsnOptions { initial: 2, max: 4, gate_capacity: 1 << 14, ..Default::default() },
    )
    .build();
    println!("[2/3] live run: {} stages, independent mid-run reconfigurations", pipeline.depth());

    let t0 = Instant::now();
    let progress = Arc::new(AtomicUsize::new(0));
    let feed = tuples.clone();
    let mut ing = pipeline.ingress.remove(0);
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(t).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let mut reader = pipeline.egress.remove(0);
    let mut got: BTreeMap<(i64, Key), u64> = BTreeMap::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    let (mut did0_up, mut did1_up, mut did0_down) = (false, false, false);
    while got.len() < oracle.len() && Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        if !did0_up && p > n / 4 {
            let e = pipeline.reconfigure_stage(0, vec![0, 1, 2]);
            println!("      @{p:>6} tuples: stage 1 (tokenize)  Π 1 → 3   (epoch {e})");
            did0_up = true;
        }
        if !did1_up && p > n / 2 {
            let e = pipeline.reconfigure_stage(1, vec![0, 1, 2, 3]);
            println!("      @{p:>6} tuples: stage 2 (wordcount) Π 2 → 4   (epoch {e})");
            did1_up = true;
        }
        if !did0_down && p > 3 * n / 4 {
            let e = pipeline.reconfigure_stage(0, vec![2]);
            println!("      @{p:>6} tuples: stage 1 (tokenize)  Π 3 → 1   (epoch {e})");
            did0_down = true;
        }
        match reader.get() {
            Some(t) if t.kind.is_data() => {
                got.insert((t.ts, t.payload.0), t.payload.1);
            }
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(100)),
        }
    }
    feeder.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    // wait for the reconfiguration completions to be recorded
    let tw = Instant::now();
    while (pipeline.stages[0].completion_times().len() < 2
        || pipeline.stages[1].completion_times().is_empty())
        && tw.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n[3/3] results:");
    let mut ok = true;
    for (k, stage) in pipeline.stages.iter().enumerate() {
        let m = stage.metrics().snapshot();
        println!(
            "      stage {} ({:<10}) in={:>8} out={:>8} tuples, Π_final={}",
            k + 1,
            stage.name(),
            m.tuples_in,
            m.tuples_out,
            stage.active_instances().len()
        );
        for (epoch, ms) in stage.completion_times() {
            let verdict = if ms < 40.0 { "✓ < 40 ms (paper bound)" } else { "" };
            println!("        reconfig epoch {epoch}: {ms:.2} ms {verdict}");
        }
    }
    let s0 = pipeline.stages[0].completion_times().len();
    let s1 = pipeline.stages[1].completion_times().len();
    if s0 < 2 || s1 < 1 {
        println!("      ✗ reconfigurations incomplete (stage1: {s0}/2, stage2: {s1}/1)");
        ok = false;
    }
    pipeline.shutdown();

    if got == oracle {
        println!(
            "      ✓ output ≡ sequential reference ({} entries) in {:.2}s wall",
            oracle.len(),
            wall
        );
    } else {
        let missing = oracle.iter().filter(|(k, v)| got.get(k) != Some(v)).count();
        let extra = got.iter().filter(|(k, _)| !oracle.contains_key(k)).count();
        println!("      ✗ output diverged: {missing} wrong/missing, {extra} extra entries");
        ok = false;
    }
    println!(
        "\n{}",
        if ok {
            "BOTH STAGES RECONFIGURED INDEPENDENTLY, OUTPUT EXACT — dag PASS"
        } else {
            "dag FAIL — see above"
        }
    );
    if !ok {
        std::process::exit(1);
    }
}
