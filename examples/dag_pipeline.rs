//! Two-stage pipeline demo, *declaratively*: the tokenize → windowed
//! wordcount topology comes from `examples/configs/dag_pipeline.conf`
//! via the JobSpec layer (the stages chain through ONE shared Elastic
//! ScaleGate, planned by the engine); this file keeps only the
//! payload-specific proof — feed a fixed tweet corpus, reconfigure both
//! stages independently mid-run, and check the final windowed counts for
//! exact equivalence against a single-threaded sequential reference.
//!
//! ```sh
//! cargo run --release --example dag_pipeline -- --tweets 30000
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stretch::cli::OrExit;
use stretch::config::Config;
use stretch::engine::JobSpec;
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::workloads::registry::{into_job_tuple, JobPayload};
use stretch::workloads::tweets::{wordcount_keys, Tweet, TweetGen, TweetGenConfig};

const DEFAULT_CONFIG: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/examples/configs/dag_pipeline.conf");

fn reference_counts(
    tuples: &[Tuple<Tweet>],
    spec: WindowSpec,
    horizon: i64,
) -> BTreeMap<(i64, Key), u64> {
    let mut m = BTreeMap::new();
    let mut keys = Vec::new();
    for t in tuples {
        keys.clear();
        wordcount_keys(t, &mut keys);
        let mut l = spec.earliest_win_l(t.ts);
        while l <= spec.latest_win_l(t.ts) {
            if l + spec.size <= horizon {
                for &k in &keys {
                    *m.entry((l + spec.size, k)).or_default() += 1;
                }
            }
            l += spec.advance;
        }
    }
    m
}

fn main() {
    let args = stretch::cli::Cli::new("dag_pipeline", "declarative 2-stage pipeline demo")
        .opt("tweets", "corpus size", Some("30000"))
        .opt("config", "job config declaring the topology", Some(DEFAULT_CONFIG))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("tweets", 30_000).or_exit();
    let path = args.str_or("config", DEFAULT_CONFIG);

    println!("═══ STRETCH multi-stage pipeline (declared in {path}) ═══\n");
    let cfg = Config::load(path).unwrap_or_else(|e| panic!("config error: {e}"));
    let job = JobSpec::from_config(&cfg).unwrap_or_else(|e| panic!("job error: {e}"));
    let count = job
        .stages
        .iter()
        .find(|s| s.operator == "word-count")
        .expect("config declares a word-count stage");
    let spec = WindowSpec::new(count.params.wa_ms, count.params.ws_ms);

    let tuples = TweetGen::new(TweetGenConfig {
        vocab: cfg.int_or("source.vocab", 3_000).max(1) as usize,
        seed: 0xDA61,
        mean_gap_ms: 1.5,
        ..Default::default()
    })
    .take(n);
    let horizon = tuples.last().unwrap().ts + 30_000;
    println!("[1/3] sequential reference: {n} tweets, {} ms windows", spec.size);
    let oracle = reference_counts(&tuples, spec, horizon);
    println!("      {} (window, word) result entries expected\n", oracle.len());

    // the topology is a config: one build() call, zero wiring here
    let mut built = job.build().unwrap_or_else(|e| panic!("job error: {e}"));
    let mut ing = built.pipeline.ingress.remove(0);
    println!(
        "[2/3] live run: {} stages ({}), independent mid-run reconfigurations",
        built.pipeline.depth(),
        built.stage_names.join(" → ")
    );

    let t0 = Instant::now();
    let progress = Arc::new(AtomicUsize::new(0));
    let feed = tuples.clone();
    let fed = progress.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(into_job_tuple(t)).unwrap();
            fed.fetch_add(1, Ordering::Relaxed);
        }
        ing.heartbeat(horizon).unwrap();
    });

    let tok = built.stage_index("tokenize").expect("config names `tokenize`");
    let cnt = built.stage_index("count").expect("config names `count`");
    // the demo's reconfig plan grows tokenize to 3 and count to 4
    // instances; fail up front if the --config override can't host it
    for (name, need) in [("tokenize", 3usize), ("count", 4usize)] {
        let st = job.stages.iter().find(|s| s.name == name).expect("stage exists");
        assert!(
            st.max >= need,
            "stage `{name}` has max = {} but the demo's reconfig plan needs max ≥ {need}",
            st.max
        );
    }
    let mut reader = built.pipeline.egress.remove(0);
    let mut got: BTreeMap<(i64, Key), u64> = BTreeMap::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    let (mut did0_up, mut did1_up, mut did0_down) = (false, false, false);
    while got.len() < oracle.len() && Instant::now() < deadline {
        let p = progress.load(Ordering::Relaxed);
        if !did0_up && p > n / 4 {
            let e = built.pipeline.reconfigure_stage(tok, vec![0, 1, 2]);
            println!("      @{p:>6} tuples: stage `tokenize` Π 1 → 3   (epoch {e})");
            did0_up = true;
        }
        if !did1_up && p > n / 2 {
            let e = built.pipeline.reconfigure_stage(cnt, vec![0, 1, 2, 3]);
            println!("      @{p:>6} tuples: stage `count`    Π 2 → 4   (epoch {e})");
            did1_up = true;
        }
        if !did0_down && p > 3 * n / 4 {
            let e = built.pipeline.reconfigure_stage(tok, vec![2]);
            println!("      @{p:>6} tuples: stage `tokenize` Π 3 → 1   (epoch {e})");
            did0_down = true;
        }
        match reader.get() {
            Some(t) if t.kind.is_data() => match &t.payload {
                JobPayload::WordCount((k, c)) => {
                    got.insert((t.ts, *k), *c);
                }
                other => panic!("wordcount sink must emit counts, got {other:?}"),
            },
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(100)),
        }
    }
    feeder.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    // wait for the reconfiguration completions to be recorded
    let tw = Instant::now();
    while (built.pipeline.stages[tok].completion_times().len() < 2
        || built.pipeline.stages[cnt].completion_times().is_empty())
        && tw.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n[3/3] results:");
    let mut ok = true;
    for (k, stage) in built.pipeline.stages.iter().enumerate() {
        let m = stage.metrics().snapshot();
        println!(
            "      stage {} ({:<10}) in={:>8} out={:>8} tuples, Π_final={}",
            built.stage_names[k],
            stage.name(),
            m.tuples_in,
            m.tuples_out,
            stage.active_instances().len()
        );
        for (epoch, ms) in stage.completion_times() {
            let verdict = if ms < 40.0 { "✓ < 40 ms (paper bound)" } else { "" };
            println!("        reconfig epoch {epoch}: {ms:.2} ms {verdict}");
        }
    }
    let s0 = built.pipeline.stages[tok].completion_times().len();
    let s1 = built.pipeline.stages[cnt].completion_times().len();
    if s0 < 2 || s1 < 1 {
        println!("      ✗ reconfigurations incomplete (tokenize: {s0}/2, count: {s1}/1)");
        ok = false;
    }
    built.pipeline.shutdown();

    if got == oracle {
        println!(
            "      ✓ output ≡ sequential reference ({} entries) in {:.2}s wall",
            oracle.len(),
            wall
        );
    } else {
        let missing = oracle.iter().filter(|(k, v)| got.get(k) != Some(v)).count();
        let extra = got.iter().filter(|(k, _)| !oracle.contains_key(k)).count();
        println!("      ✗ output diverged: {missing} wrong/missing, {extra} extra entries");
        ok = false;
    }
    println!(
        "\n{}",
        if ok {
            "CONFIG-DECLARED PIPELINE: BOTH STAGES RECONFIGURED, OUTPUT EXACT — PASS"
        } else {
            "dag FAIL — see above"
        }
    );
    if !ok {
        std::process::exit(1);
    }
}
