//! §Perf probe: isolate the ScaleJoin O+ per-thread loop from engine
//! threading (single thread drives the core directly), vs the live
//! engine (threads share this 1-core box), vs the 1T baseline.
use stretch::metrics::OperatorMetrics;
use stretch::operator::state::SharedState;
use stretch::operator::{Ctx, OperatorCore};
use stretch::tuple::Mapper;
use stretch::workloads::scalejoin_bench::{q3_operator, OneT, SjGen};

fn main() {
    let nk: u64 = std::env::var("NK").ok().and_then(|v| v.parse().ok()).unwrap_or(64);
    let ws = 5000i64;
    // --- core-only (no engine threads) ---
    let def = q3_operator(ws, nk);
    let mut core = OperatorCore::new(def, 0, SharedState::new(64), OperatorMetrics::new(1));
    let f_mu = Mapper::hash_mod(1);
    let mut gen = SjGen::new(9, 20_000.0);
    for t in gen.take(30_000) {
        let mut sink = |_o| {};
        let mut ctx = Ctx::new(&mut sink);
        core.process(&t, &f_mu, &mut ctx); // warm window
    }
    let t0 = std::time::Instant::now();
    let mut cmp = 0u64;
    let mut n = 0u64;
    while t0.elapsed().as_millis() < 3000 {
        for t in gen.take(1024) {
            let mut sink = |_o| {};
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
            cmp += ctx.comparisons;
        }
        n += 1024;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("core-only: {:.1}M cmp/s, {:.0} t/s processed", cmp as f64 / dt / 1e6, n as f64 / dt);
    // --- 1T ---
    let mut gen = SjGen::new(9, 20_000.0);
    let mut j = OneT::new(ws);
    for t in gen.take(30_000) { j.process(&t); }
    let c0 = j.comparisons;
    let t1 = std::time::Instant::now();
    while t1.elapsed().as_millis() < 3000 {
        for t in gen.take(1024) { j.process(&t); }
    }
    println!("1T:        {:.1}M cmp/s", (j.comparisons - c0) as f64 / t1.elapsed().as_secs_f64() / 1e6);
}
