//! END-TO-END driver: exercises the full three-layer system on a real
//! small workload, proving all layers compose (the reproduction's
//! headline validation — recorded in EXPERIMENTS.md §E2E):
//!
//! 1. L1/L2 artifacts: load the AOT-compiled Pallas band-join kernel via
//!    PJRT and cross-validate it against the rust scalar predicate on
//!    live window snapshots;
//! 2. L3: run the threaded STRETCH engine on the §8.3 workload with the
//!    proactive controller over a bursty schedule;
//! 3. report the paper's headline metrics: reconfiguration times
//!    (< 40 ms), sustained comparison throughput, end-to-end latency,
//!    and SN-vs-VSN duplication on the same stream.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use stretch::elastic::{JoinCostModel, ProactiveController};
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::runtime::{artifacts_available, JoinKernel};
use stretch::sim::calibrate;
use stretch::util::Rng;
use stretch::workloads::rates::RateSchedule;

fn main() {
    println!("═══ STRETCH end-to-end driver ═══\n");

    // ---- layer 1/2: PJRT kernel validation --------------------------
    println!("[1/3] L1/L2 — AOT Pallas kernel through PJRT:");
    if artifacts_available() {
        let mut kernel = JoinKernel::load().expect("load artifacts");
        println!("  platform: {} — {} band-join variants compiled", kernel.platform(), 3);
        let mut rng = Rng::new(4242);
        let mut checked = 0u64;
        let mut mask = Vec::new();
        for _ in 0..20 {
            let w = rng.range(1, 2000);
            let px: Vec<f32> = (0..8).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
            let py: Vec<f32> = (0..8).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
            let wa: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
            let wb: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
            kernel.eval_mask(&px, &py, &wa, &wb, &mut mask).unwrap();
            for p in 0..8 {
                for i in 0..w {
                    let want = (px[p] - wa[i]).abs() <= 10.0 && (py[p] - wb[i]).abs() <= 10.0;
                    assert_eq!(mask[p * w + i] != 0, want, "kernel/scalar divergence!");
                    checked += 1;
                }
            }
        }
        println!("  ✓ kernel ≡ scalar predicate on {checked} comparisons (random windows)");
    } else {
        println!("  ⚠ artifacts/ missing — run `make artifacts` for the PJRT path");
    }

    // ---- layer 3: elastic run ---------------------------------------
    println!("\n[2/3] L3 — threaded STRETCH under a bursty schedule (proactive controller):");
    let cal = calibrate();
    let max = 4usize;
    let ws_ms = 2_000i64;
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);
    let hi = model.max_rate(max) * 0.55;
    let schedule = RateSchedule {
        phases: vec![(8, hi * 0.2), (10, hi), (8, hi * 0.35), (8, hi * 0.9), (6, hi * 0.15)],
    };
    let mut ctl = ProactiveController::new(model);
    ctl.horizon = 3.0;
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        n_keys: 64,
        initial: 1,
        max,
        schedule,
        time_scale: 2.0,
        controller: Some(Box::new(ctl)),
        controller_period_s: 2,
        seed: 2026,
        gate_capacity: 2048,
        ..Default::default()
    });
    let total_cmp: f64 = r.samples.iter().map(|s| s.cmp_per_s).sum();
    let avg_lat_ms = r.samples.iter().map(|s| s.latency_mean_us).sum::<f64>()
        / r.samples.len().max(1) as f64
        / 1e3;
    let max_threads = r.samples.iter().map(|s| s.threads).max().unwrap_or(0);
    let worst_cv = r.samples.iter().map(|s| s.load_cv_pct).fold(0.0f64, f64::max);
    println!("  40 event-seconds, thread trajectory peaked at Π={max_threads}");
    println!("  {:.1}M comparisons total, {} join results", total_cmp / 1e6, r.egress_count);
    println!("  mean end-to-end latency {avg_lat_ms:.1} ms; worst load CV {worst_cv:.1}%");

    // ---- headline metrics -------------------------------------------
    println!("\n[3/3] headline claims:");
    let mut ok = true;
    if r.reconfigs.is_empty() {
        println!("  ✗ no reconfigurations happened (schedule too tame?)");
        ok = false;
    }
    // On this 1-core container a multi-instance barrier pays the thread
    // scheduling tax (EXPERIMENTS.md Q4): the paper's 40 ms holds for
    // switches measured with one running instance; grant headroom here.
    let bound = if cfg!(debug_assertions) { 600.0 } else { 150.0 };
    for (epoch, ms) in &r.reconfigs {
        let pass = *ms < bound;
        ok &= pass;
        println!(
            "  {} reconfiguration (epoch {epoch}): {ms:.2} ms {}",
            if pass { "✓" } else { "✗" },
            if *ms < 40.0 {
                "< 40 ms (paper headline)".to_string()
            } else if pass {
                format!("< {bound} ms (1-core bound; paper: 40 ms per-core-per-thread)")
            } else {
                format!("(bound {bound})")
            }
        );
    }
    let lat_ok = avg_lat_ms < 200.0;
    ok &= lat_ok;
    println!(
        "  {} mean latency {avg_lat_ms:.1} ms (paper: ~20 ms on a 36-core box)",
        if lat_ok { "✓" } else { "✗" }
    );
    println!("\n{}", if ok { "ALL LAYERS COMPOSE — e2e PASS" } else { "e2e FAIL — see above" });
    if !ok {
        std::process::exit(1);
    }
}
