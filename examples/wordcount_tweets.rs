//! Wordcount over the synthetic tweet corpus (the Q1 workload), showing
//! the VSN advantage over SN duplication *and* the running example from
//! the paper's introduction (longest tweet per hashtag) on a second
//! operator.
//!
//! ```sh
//! cargo run --release --example wordcount_tweets -- --tweets 20000
//! ```

use stretch::cli::OrExit;
use std::time::Duration;
use stretch::engine::{VsnEngine, VsnOptions};
use stretch::time::WindowSpec;
use stretch::workloads::tweets::{duplication_factor, paircount_keys, wordcount_keys, TweetGen, TweetGenConfig};
use stretch::workloads::{longest_tweet_op, wordcount_op};

fn main() {
    let args = stretch::cli::Cli::new("wordcount_tweets", "Q1-style wordcount demo")
        .opt("tweets", "corpus size", Some("20000"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("tweets", 20_000).or_exit();

    let mut gen = TweetGen::new(TweetGenConfig { vocab: 8_000, seed: 99, ..Default::default() });
    let tuples = gen.take(n);
    println!("corpus: {n} synthetic tweets (Zipf vocabulary)");
    println!("duplication factors (keys/tuple — what SN must clone, VSN shares):");
    println!("  wordcount: {:.1}", duplication_factor(&tuples, wordcount_keys));
    println!("  paircount L/M/H: {:.1} / {:.1} / {:.1}",
        duplication_factor(&tuples, paircount_keys(3)),
        duplication_factor(&tuples, paircount_keys(10)),
        duplication_factor(&tuples, paircount_keys(usize::MAX)));

    // ---- wordcount A+ on the VSN engine ----------------------------
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        wordcount_op(WindowSpec::new(60_000, 120_000)), // Operator 4 geometry
        VsnOptions { initial: 2, max: 2, upstreams: 1, ..Default::default() },
    );
    let mut ing = ingress.remove(0);
    let mut out = readers.remove(0);
    let horizon = tuples.last().unwrap().ts + 200_000;
    let feed = tuples.clone();
    let feeder = std::thread::spawn(move || {
        for t in feed {
            ing.add(t).unwrap();
        }
        ing.heartbeat(horizon).unwrap();
    });
    let mut counts: Vec<(u64, u64)> = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut quiet = std::time::Instant::now();
    while std::time::Instant::now() < deadline {
        match out.get() {
            Some(t) if t.kind.is_data() => {
                counts.push(t.payload);
                quiet = std::time::Instant::now();
            }
            Some(_) => {}
            None => {
                if feeder.is_finished() && quiet.elapsed() > Duration::from_millis(300) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    feeder.join().unwrap();
    engine.shutdown();
    // aggregate across windows: top words overall
    let mut totals = std::collections::HashMap::<u64, u64>::new();
    for &(k, c) in &counts {
        *totals.entry(k).or_default() += c;
    }
    let mut top: Vec<_> = totals.into_iter().collect();
    top.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    println!("\ntop words (id, windowed-count sum):");
    for (k, c) in top.iter().take(8) {
        println!("  word#{k}: {c}");
    }
    println!("({} window results total)", counts.len());

    // ---- the §1 running example: longest tweet per hashtag ---------
    let (mut engine2, mut ingress2, mut readers2) = VsnEngine::setup(
        longest_tweet_op(WindowSpec::new(1_800_000, 3_600_000)), // 30m/60m (Operator 1)
        VsnOptions { initial: 2, max: 2, upstreams: 1, ..Default::default() },
    );
    let mut ing2 = ingress2.remove(0);
    let mut out2 = readers2.remove(0);
    let horizon2 = tuples.last().unwrap().ts + 7_200_000;
    let feeder2 = std::thread::spawn(move || {
        for t in tuples {
            ing2.add(t).unwrap();
        }
        ing2.heartbeat(horizon2).unwrap();
    });
    let mut longest: Vec<(u64, u64)> = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut quiet = std::time::Instant::now();
    while std::time::Instant::now() < deadline {
        match out2.get() {
            Some(t) if t.kind.is_data() => {
                longest.push(t.payload);
                quiet = std::time::Instant::now();
            }
            Some(_) => {}
            None => {
                if feeder2.is_finished() && quiet.elapsed() > Duration::from_millis(300) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    feeder2.join().unwrap();
    engine2.shutdown();
    longest.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    println!("\nlongest tweet per hashtag (the §1 running example, A+ with f_MK):");
    for (tag, chars) in longest.iter().take(5) {
        println!("  #tag{tag}: {chars} chars");
    }
}
