//! Elastic ScaleJoin: the Q4/Q5 scenario as a runnable demo — a live
//! threaded STRETCH join under a stepping rate, with the reactive
//! controller provisioning and decommissioning instances on the fly.
//!
//! ```sh
//! cargo run --release --example elastic_scalejoin
//! ```

use stretch::cli::OrExit;
use stretch::elastic::{JoinCostModel, ReactiveController, Thresholds};
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::sim::calibrate;
use stretch::workloads::rates::RateSchedule;

fn main() {
    let args = stretch::cli::Cli::new("elastic_scalejoin", "live elastic ScaleJoin demo")
        .opt("ws-ms", "window size ms", Some("2000"))
        .opt("max", "max parallelism", Some("4"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let ws_ms = args.u64_or("ws-ms", 2_000).or_exit() as i64;
    let max = args.usize_or("max", 4).or_exit();

    println!("calibrating the join cost model on this machine...");
    let cal = calibrate();
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);
    let r1 = model.max_rate(1);
    println!("  1-thread sustainable rate ≈ {r1:.0} t/s (WS = {ws_ms} ms)\n");

    // rate staircase: under → over → way over → back down
    let schedule = RateSchedule {
        phases: vec![
            (6, 0.6 * r1),
            (8, 1.5 * r1),
            (8, 2.6 * r1),
            (8, 0.4 * r1),
        ],
    };
    let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(2);
    println!("running 30 event-seconds (compressed 2×) with the 90/70/45 reactive controller:");
    println!("  t  offered(t/s) served  cmp/s      lat(ms)  Π  backlog  loadCV%");
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        initial: 1,
        max,
        schedule,
        time_scale: 2.0,
        controller: Some(Box::new(ctl)),
        controller_period_s: 1,
        ..Default::default()
    });
    for s in &r.samples {
        println!(
            "{:>4} {:>10.0} {:>8.0} {:>10.2e} {:>8.1} {:>2} {:>8} {:>7.1}",
            s.t_s,
            s.offered_tps,
            s.in_tps,
            s.cmp_per_s,
            s.latency_mean_us / 1e3,
            s.threads,
            s.backlog,
            s.load_cv_pct
        );
    }
    println!("\nreconfigurations (epoch, wall ms):");
    for (e, ms) in &r.reconfigs {
        let verdict = if *ms < 40.0 { "✓ < 40 ms" } else { "over paper bound" };
        println!("  epoch {e}: {ms:.2} ms  {verdict}");
    }
    println!("\n{} join results reached the egress", r.egress_count);
}
